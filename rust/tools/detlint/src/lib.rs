//! detlint — SPMD determinism & collective-discipline analysis for the
//! `sfc_part` tree.
//!
//! The repo's correctness story rests on two contracts that no compiler
//! checks: every rank issues the *same* collective sequence (divergence
//! deadlocks the simulated fabric), and every pipeline is bit-identical
//! across thread counts. detlint enforces the mechanical half of both as
//! lint rules over a token-level scan of the source:
//!
//! | rule id                 | what it flags                                  |
//! |-------------------------|------------------------------------------------|
//! | `collective-divergence` | collectives under rank-local conditionals or   |
//! |                         | after rank-local early returns (R1)            |
//! | `count-lane-f64`        | count-like `as f64` casts feeding f64          |
//! |                         | collective lanes (R2)                          |
//! | `hash-iteration`        | HashMap/HashSet iteration in determinism-      |
//! |                         | critical modules (R3)                          |
//! | `unseeded-rng`          | entropy-seeded RNGs in those modules (R3)      |
//! | `timing-in-compute`     | clock / thread-count reads in compute (R3)     |
//! | `float-sort-order`      | `partial_cmp` comparators in sorts (R3)        |
//! | `unsafe-missing-safety` | `unsafe` without a `// SAFETY:` comment (R4)   |
//! | `branch-congruence`     | conditional arms with divergent *transitive*   |
//! |                         | collective effect: calls issuing collectives   |
//! |                         | inside rank-local branches or after rank-local |
//! |                         | early returns; non-rank-local arms that both   |
//! |                         | issue collectives but different ones (R5)      |
//! | `loop-divergence`       | non-empty transitive collective effect inside  |
//! |                         | a loop whose bound is rank-local (R6)          |
//! | `epoch-arithmetic`      | `fabric.send/recv` tags not derived from       |
//! |                         | `next_epoch`/`alloc_tags`; manual `epoch +=`   |
//! |                         | outside `rank.rs`; a collective whose          |
//! |                         | documented tag-allocation sites don't match    |
//! |                         | its body (R7)                                  |
//!
//! Findings are suppressible only by an inline
//! `// detlint: allow(<rule>) -- <justification>` on the flagged line or
//! the contiguous comment block above it; an allow *without* the
//! `-- <justification>` tail is itself reported
//! (`allow-missing-justification`).
//!
//! The scanner is a hand-rolled lexer + scope walk (no syn: the build
//! environment is offline and this tree vendors no third-party code).
//! R1–R4 are intentionally lexical — they see through no function calls —
//! while R5–R7 ride the interprocedural layer in [`interproc`]: a
//! crate-wide call graph whose per-function *collective effect
//! signatures* (ordered collective sequences with symbolic `loop{…}` /
//! `alt{a|b}` nodes) propagate bottom-up through call sites. The same
//! layer powers `detlint --trace`, whose flattened per-entry-point
//! traces the runtime test `rust/tests/trace_congruence.rs` cross-checks
//! against the debug-build fabric congruence recorder. All rules are
//! calibrated to zero false positives on the shipped tree; see
//! `tests/fixtures/` for the known-bad snippets each rule must catch.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

pub mod interproc;

pub use interproc::{
    analyze_files, has_coll, sig_name, trace_matches, trace_str, CrateAnalysis, EntryTrace,
    TraceNode, EPOCH_SITES,
};

/// Determinism-critical module directories: R3 rules apply only to
/// files whose path contains one of these components.
const DET_DIRS: &[&str] = &["partition", "sfc", "migrate", "runtime_sim", "kdtree"];

/// Files that *implement* the collectives: their internal rank-dependent
/// sends are the algorithm, not a divergence, so R1 skips them.
const R1_EXEMPT_SUFFIX: &[&str] = &[
    "runtime_sim/collectives.rs",
    "runtime_sim/fabric.rs",
    "runtime_sim/rank.rs",
    "runtime_sim/mod.rs",
];

const COLLECTIVES: &[&str] = &[
    "barrier",
    "allreduce1",
    "allreduce_f64",
    "allreduce_u64",
    "allreduce_multi",
    "allreduce_f64_multi",
    "reduce_f64",
    "broadcast_bytes",
    "broadcast_f64",
    "exscan_f64",
    "exscan_u64",
    "exscan_u64_many",
    "gather_bytes",
    "allgather_bytes",
    "alltoallv",
    "alltoallv_rounds",
    "reduce_scatter_f64",
];

/// Collective entry points whose payload rides an f64 lane (R2 sinks).
const F64_SINKS: &[&str] = &[
    "exscan_f64",
    "allreduce_f64",
    "allreduce_f64_multi",
    "allreduce1",
    "reduce_f64",
    "reduce_scatter_f64",
];

const TIMING: &[&str] = &["thread_cpu_time", "process_cpu_time", "available_parallelism"];

const RNG_BAD: &[&str] = &["thread_rng", "from_entropy"];

/// One lint finding, with a stable rule id and the flagged line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// One-line fix hint per rule id, shown next to each finding.
pub fn hint_for(rule: &str) -> &'static str {
    match rule {
        "collective-divergence" => {
            "hoist the collective out of the rank-local branch (every rank \
             must issue it), or allow with a justification if the condition \
             is provably SPMD-uniform"
        }
        "count-lane-f64" => {
            "route counts/ids through a Section::U64 / exscan_u64 lane — \
             f64 silently absorbs +1 beyond 2^53"
        }
        "hash-iteration" => {
            "iterate a BTreeMap/BTreeSet or sort the keys first — HashMap \
             order is seeded per process"
        }
        "unseeded-rng" => "use util::rng::SplitMix64 with a fixed seed",
        "timing-in-compute" => {
            "keep clock reads in the timer/report layer; compute must not \
             branch on time"
        }
        "float-sort-order" => "use f64::total_cmp — partial_cmp panics or reorders on NaN",
        "unsafe-missing-safety" => {
            "precede the unsafe block/impl with a `// SAFETY:` comment \
             stating the invariant"
        }
        "branch-congruence" => {
            "make every arm issue the same collective sequence (hoist the \
             call out of the branch), or allow with the uniformity \
             invariant stated if the condition is provably SPMD-uniform"
        }
        "loop-divergence" => {
            "derive the loop bound from collective-agreed values (every \
             rank must run the same number of collective-bearing \
             iterations), or allow with the invariant stated"
        }
        "epoch-arithmetic" => {
            "allocate tags with `next_epoch()`/`alloc_tags(n)` (and keep \
             the EPOCH_SITES table in detlint in sync) — manual epoch \
             arithmetic drifts the tag namespace between ranks"
        }
        "allow-missing-justification" => "write `// detlint: allow(<rule>) -- why this is sound`",
        _ => "",
    }
}

/// Machine-readable findings (the `--format json` output): a stable
/// array of `{file, line, rule, msg, hint}` objects, sorted like the
/// human output.
pub fn findings_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"file\": {}, \"line\": {}, \"rule\": {}, \"msg\": {}, \"hint\": {}}}",
            json_str(&f.file),
            f.line,
            json_str(f.rule),
            json_str(&f.msg),
            json_str(hint_for(f.rule)),
        ));
    }
    if !findings.is_empty() {
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

/// The allow comment covering `findline` for `rule`, if any: on the
/// line itself or in the contiguous comment-only block directly above.
pub(crate) fn allow_comment(
    comments: &BTreeMap<usize, String>,
    code_lines: &BTreeSet<usize>,
    findline: usize,
    rule: &str,
) -> Option<String> {
    let pat = format!("detlint: allow({rule})");
    let has = |l: usize| -> bool {
        comments.get(&l).is_some_and(|t| t.contains(&pat) || t.contains("detlint: allow(all)"))
    };
    if has(findline) {
        return comments.get(&findline).cloned();
    }
    let mut l = findline.saturating_sub(1);
    while l > 0 && comments.contains_key(&l) && !code_lines.contains(&l) {
        if has(l) {
            return comments.get(&l).cloned();
        }
        l -= 1;
    }
    None
}

/// Push a finding unless an allow comment suppresses it; an allow
/// without the `-- <justification>` tail is itself a finding.
pub(crate) fn push_checked(
    findings: &mut Vec<Finding>,
    comments: &BTreeMap<usize, String>,
    code_lines: &BTreeSet<usize>,
    rel: &str,
    rule: &'static str,
    line: usize,
    msg: String,
) {
    if let Some(just) = allow_comment(comments, code_lines, line, rule) {
        if !just.contains("--") {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: "allow-missing-justification",
                msg: format!("allow({rule}) has no `-- <justification>` tail"),
            });
        }
        return;
    }
    findings.push(Finding { file: rel.to_string(), line, rule, msg });
}

/// Escape a string as a JSON string literal.
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Collect `.rs` files under `root`, sorted for deterministic output.
pub fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return;
    }
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut children: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    children.sort();
    for child in children {
        if child.is_dir() {
            collect_rs_files(&child, out);
        } else if child.extension().is_some_and(|e| e == "rs") {
            out.push(child);
        }
    }
}

/// Read every `.rs` file under `root` into `(rel_path, source)` pairs —
/// the input shape [`analyze_files`] wants. Paths are reported relative
/// to `root`.
pub fn read_tree(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files);
    let mut out = Vec::with_capacity(files.len());
    for file in files {
        let src = std::fs::read_to_string(&file)?;
        let rel = match file.strip_prefix(root) {
            Ok(r) if !r.as_os_str().is_empty() => r.display().to_string(),
            _ => file.display().to_string(),
        };
        out.push((rel, src));
    }
    Ok(out)
}

#[derive(Debug, Clone)]
struct Tok {
    line: usize,
    text: String,
    is_ident: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ScopeKind {
    File,
    If,
    Else,
    While,
    For,
    Match,
    Fn,
    Closure,
    Loop,
    Mod,
    Block,
}

struct Scope {
    kind: ScopeKind,
    rank_local: Option<String>,
    test: bool,
    divergent_return: bool,
}

impl Scope {
    fn plain(kind: ScopeKind) -> Scope {
        Scope { kind, rank_local: None, test: false, divergent_return: false }
    }

    fn with_cond(kind: ScopeKind, rank_local: Option<String>) -> Scope {
        Scope { kind, rank_local, test: false, divergent_return: false }
    }
}

fn slice_text(b: &[u8], i: usize, j: usize) -> String {
    let j = j.min(b.len());
    let i = i.min(j);
    String::from_utf8_lossy(&b[i..j]).into_owned()
}

/// Consume a char literal or lifetime starting at the `'` at `i`;
/// returns the index just past it.
fn lex_char_or_lifetime(b: &[u8], i: usize) -> usize {
    let n = b.len();
    if i + 2 < n && b[i + 1] == b'\\' {
        let mut j = i + 2;
        while j < n && b[j] != b'\'' {
            j += 1;
        }
        return (j + 1).min(n);
    }
    if i + 2 < n && b[i + 2] == b'\'' {
        return i + 3;
    }
    let mut j = i + 1;
    while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
        j += 1;
    }
    j
}

/// Tokenize Rust-ish source: idents and single-char punctuation, with
/// per-line comment text collected on the side. Strings, chars,
/// lifetimes, and numeric literals are consumed but produce no tokens —
/// the rules only ever look at idents and punctuation.
fn lex(src: &str) -> (Vec<Tok>, BTreeMap<usize, String>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            let text = slice_text(b, i, j);
            comments.entry(line).or_default().push_str(&text);
            i = j;
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start_line = line;
            let mut depth = 1i64;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'\n' {
                    line += 1;
                    j += 1;
                    continue;
                }
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                    continue;
                }
                if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                    continue;
                }
                j += 1;
            }
            let text = slice_text(b, i, j);
            comments.entry(start_line).or_default().push_str(&text);
            i = j;
            continue;
        }
        if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                if b[j] == b'"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            i = j;
            continue;
        }
        if c == b'r' || c == b'b' {
            // raw / byte strings: r".."  r#".."#  br".."  b".."  b'x'
            let mut k = i;
            if b[k] == b'b' && k + 1 < n && b[k + 1] == b'r' {
                k += 1;
            }
            if b[k] == b'r' {
                let mut j = k + 1;
                let mut hashes = 0usize;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    let mut e = j + 1;
                    let end = loop {
                        if e >= n {
                            break n;
                        }
                        if b[e] == b'"' {
                            let mut h = 0usize;
                            while h < hashes && e + 1 + h < n && b[e + 1 + h] == b'#' {
                                h += 1;
                            }
                            if h == hashes {
                                break e + 1 + hashes;
                            }
                        }
                        e += 1;
                    };
                    for &ch in &b[i..end] {
                        if ch == b'\n' {
                            line += 1;
                        }
                    }
                    i = end;
                    continue;
                }
            }
            if c == b'b' && i + 1 < n && b[i + 1] == b'"' {
                let mut j = i + 2;
                while j < n {
                    if b[j] == b'\\' {
                        j += 2;
                        continue;
                    }
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    if b[j] == b'"' {
                        j += 1;
                        break;
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
            if c == b'b' && i + 1 < n && b[i + 1] == b'\'' {
                i = lex_char_or_lifetime(b, i + 1);
                continue;
            }
            // plain ident starting with r/b: fall through
        }
        if c == b'\'' {
            i = lex_char_or_lifetime(b, i);
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            toks.push(Tok { line, text: slice_text(b, i, j), is_ident: true });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            // numeric literal; a fractional `.` must not swallow a method
            // name (`a.1.partial_cmp`) or a range (`0..n`)
            let mut j = i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            if j < n && b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        toks.push(Tok { line, text: (c as char).to_string(), is_ident: false });
        i += 1;
    }
    (toks, comments)
}

fn is_det_critical(rel: &str) -> bool {
    let norm = rel.replace('\\', "/");
    norm.split('/').any(|p| DET_DIRS.contains(&p))
}

fn is_countish_ident(s: &str) -> bool {
    const NAMES: &[&str] = &[
        "count", "counts", "cnt", "n", "total", "size", "num", "id", "ids", "idx", "lower", "len",
    ];
    if NAMES.contains(&s) {
        return true;
    }
    s.contains("count") || s.ends_with("_len") || s.starts_with("n_")
}

fn any_test(stack: &[Scope]) -> bool {
    stack.iter().any(|s| s.test)
}

fn enclosing_rank_local(stack: &[Scope]) -> Option<String> {
    let mut why: Option<String> = None;
    for s in stack {
        let conditional = matches!(
            s.kind,
            ScopeKind::If | ScopeKind::Else | ScopeKind::While | ScopeKind::For | ScopeKind::Match
        );
        if conditional {
            if let Some(w) = &s.rank_local {
                why = Some(w.clone());
            }
        }
    }
    why
}

fn innermost_fn_idx(stack: &[Scope]) -> usize {
    for (i, s) in stack.iter().enumerate().rev() {
        if matches!(s.kind, ScopeKind::Fn | ScopeKind::Closure | ScopeKind::File) {
            return i;
        }
    }
    0
}

/// Idents bound (or typed) as HashMap/HashSet in this file: the targets
/// of the hash-iteration rule. Covers `let [mut] name: HashMap<..>`,
/// struct fields `name: HashMap<..>`, and `name = HashMap::new()`, each
/// optionally through a `std::collections::` path.
fn collect_hash_idents(toks: &[Tok]) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for k in 1..toks.len() {
        let t = &toks[k];
        if !(t.is_ident && (t.text == "HashMap" || t.text == "HashSet")) {
            continue;
        }
        let mut j: i64 = k as i64 - 1;
        loop {
            let path_seg = j >= 2
                && toks[j as usize].text == ":"
                && toks[(j - 1) as usize].text == ":"
                && matches!(toks[(j - 2) as usize].text.as_str(), "std" | "collections");
            if path_seg {
                j -= 3;
            } else {
                break;
            }
        }
        if j >= 1 {
            let jt = toks[j as usize].text.clone();
            let p = &toks[(j - 1) as usize];
            if (jt == ":" || jt == "=")
                && p.is_ident
                && !matches!(p.text.as_str(), "mut" | "let" | "pub")
            {
                out.insert(p.text.clone());
            }
        }
    }
    out
}

struct Analyzer {
    rel: String,
    toks: Vec<Tok>,
    comments: BTreeMap<usize, String>,
    code_lines: BTreeSet<usize>,
    det: bool,
    r1_on: bool,
    hash_idents: BTreeSet<String>,
    findings: Vec<Finding>,
}

impl Analyzer {
    fn text(&self, k: usize) -> &str {
        self.toks[k].text.as_str()
    }

    fn emit(&mut self, rule: &'static str, line: usize, msg: String) {
        push_checked(
            &mut self.findings,
            &self.comments,
            &self.code_lines,
            &self.rel,
            rule,
            line,
            msg,
        );
    }

    fn cond_rank_local(&self, ctoks: &[usize]) -> Option<String> {
        for (w, &i) in ctoks.iter().enumerate() {
            let t = &self.toks[i];
            if !t.is_ident {
                continue;
            }
            let s = t.text.as_str();
            if s == "rank" {
                return Some("condition reads `rank`".to_string());
            }
            if s == "is_root" {
                return Some("condition calls `is_root()`".to_string());
            }
            let len_like = s == "len" || s == "is_empty";
            if len_like && w > 0 && self.text(ctoks[w - 1]) == "." {
                return Some(format!("condition reads a rank-local `{s}()`"));
            }
        }
        None
    }

    /// R2 plus the float-sort statement check run at statement
    /// boundaries; `stmt` holds token indices since the last boundary.
    fn check_stmt(&mut self, stmt: &mut Vec<usize>, stack: &[Scope]) {
        if stmt.is_empty() || any_test(stack) {
            stmt.clear();
            return;
        }
        let mut has_sink = stmt.iter().any(|&i| {
            let t = &self.toks[i];
            t.is_ident && F64_SINKS.contains(&t.text.as_str())
        });
        if !has_sink && stmt.len() >= 4 {
            for w in 0..stmt.len() - 3 {
                let section = self.text(stmt[w]) == "Section"
                    && self.text(stmt[w + 1]) == ":"
                    && self.text(stmt[w + 2]) == ":"
                    && self.text(stmt[w + 3]) == "F64";
                if section {
                    has_sink = true;
                    break;
                }
            }
        }
        if has_sink {
            let lines = self.count_cast_lines(stmt);
            for line in lines {
                self.emit(
                    "count-lane-f64",
                    line,
                    "count-like value cast `as f64` feeds an f64 collective lane".to_string(),
                );
            }
        }
        stmt.clear();
    }

    /// Lines inside `stmt` where a count-like value is cast `as f64`.
    fn count_cast_lines(&self, stmt: &[usize]) -> Vec<usize> {
        let mut out = Vec::new();
        for w in 0..stmt.len() {
            let t = &self.toks[stmt[w]];
            if !(t.is_ident && t.text == "as") {
                continue;
            }
            if w + 1 >= stmt.len() || self.text(stmt[w + 1]) != "f64" {
                continue;
            }
            let mut countish = false;
            if w >= 4 && self.text(stmt[w - 1]) == ")" {
                let call = self.text(stmt[w - 3]);
                let dot = self.text(stmt[w - 4]);
                if matches!(call, "len" | "count" | "nnz") && dot == "." {
                    countish = true;
                }
            }
            if w >= 1 {
                let p = &self.toks[stmt[w - 1]];
                if p.is_ident && is_countish_ident(&p.text) {
                    countish = true;
                }
            }
            if countish {
                out.push(t.line);
            }
        }
        out
    }

    /// R3 det-hygiene checks over a captured `if`/`while`/`match`/`for`
    /// header (those tokens never reach the main statement walk).
    fn scan_cond_header(&mut self, kind: ScopeKind, ctoks: &[usize]) {
        for w in 0..ctoks.len() {
            let (ln, s, isid) = {
                let t = &self.toks[ctoks[w]];
                (t.line, t.text.clone(), t.is_ident)
            };
            if !isid {
                continue;
            }
            self.check_rng(&s, ln);
            let called = w + 1 < ctoks.len() && self.text(ctoks[w + 1]) == "(";
            self.check_timing_call(&s, called, ln);
            if s == "now" && w >= 3 {
                let a = self.text(ctoks[w - 1]).to_string();
                let b = self.text(ctoks[w - 2]).to_string();
                let c = self.text(ctoks[w - 3]).to_string();
                self.check_clock_now(&a, &b, &c, ln);
            }
            if matches!(s.as_str(), "iter" | "keys" | "values" | "drain" | "into_iter") && w >= 2 {
                let mut name: Option<String> = None;
                {
                    let prev = &self.toks[ctoks[w - 2]];
                    let dotted = self.text(ctoks[w - 1]) == ".";
                    if dotted && prev.is_ident && self.hash_idents.contains(&prev.text) {
                        name = Some(prev.text.clone());
                    }
                }
                if let Some(name) = name {
                    self.emit("hash-iteration", ln, format!("iteration over hash-ordered `{name}`"));
                }
            }
            if s == "in" && kind == ScopeKind::For && w + 1 < ctoks.len() {
                let mut cj = w + 1;
                while cj < ctoks.len() && matches!(self.text(ctoks[cj]), "&" | "mut") {
                    cj += 1;
                }
                let mut name: Option<String> = None;
                if cj < ctoks.len() {
                    let t2 = &self.toks[ctoks[cj]];
                    let next_dot = cj + 1 < ctoks.len() && self.text(ctoks[cj + 1]) == ".";
                    if t2.is_ident && self.hash_idents.contains(&t2.text) && !next_dot {
                        name = Some(t2.text.clone());
                    }
                }
                if let Some(name) = name {
                    self.emit("hash-iteration", ln, format!("iteration over hash-ordered `{name}`"));
                }
            }
        }
    }

    fn check_rng(&mut self, s: &str, ln: usize) {
        if RNG_BAD.contains(&s) {
            let msg = format!("entropy-seeded RNG `{s}` in a determinism-critical module");
            self.emit("unseeded-rng", ln, msg);
        }
    }

    fn check_timing_call(&mut self, s: &str, called: bool, ln: usize) {
        if TIMING.contains(&s) && called {
            let msg = format!("clock/thread-count read `{s}()` in a determinism-critical module");
            self.emit("timing-in-compute", ln, msg);
        }
    }

    fn check_clock_now(&mut self, a: &str, b: &str, c: &str, ln: usize) {
        if a == ":" && b == ":" && (c == "Instant" || c == "SystemTime") {
            let msg = format!("`{c}::now()` in a determinism-critical module");
            self.emit("timing-in-compute", ln, msg);
        }
    }

    fn run(&mut self) {
        let ntoks = self.toks.len();
        let mut stack: Vec<Scope> = vec![Scope::plain(ScopeKind::File)];
        let mut pending_cond: Option<(ScopeKind, Vec<usize>)> = None;
        let mut cond_paren = 0i64;
        let mut last_if_flag: BTreeMap<usize, Option<String>> = BTreeMap::new();
        let mut pending_else = false;
        let mut pending_kw: Option<ScopeKind> = None;
        let mut pending_test_attr = false;
        let mut stmt: Vec<usize> = Vec::new();
        let mut paren_depth = 0i64;
        let mut sort_calls: Vec<i64> = Vec::new();

        let mut k = 0usize;
        while k < ntoks {
            let ln = self.toks[k].line;
            let txt = self.toks[k].text.clone();
            let isid = self.toks[k].is_ident;
            stmt.push(k);

            // -- float-sort tracking: `partial_cmp` anywhere inside a
            // sort/max/min call's argument list (R3)
            if txt == "(" {
                paren_depth += 1;
            } else if txt == ")" {
                paren_depth -= 1;
                while sort_calls.last().is_some_and(|&d| paren_depth < d) {
                    sort_calls.pop();
                }
            }
            let sort_name = matches!(
                txt.as_str(),
                "sort_by" | "sort_unstable_by" | "max_by" | "min_by" | "sort_by_cached_key"
            );
            if isid && sort_name && k + 1 < ntoks && self.text(k + 1) == "(" {
                sort_calls.push(paren_depth + 1);
            }
            let in_sort = self.det && isid && txt == "partial_cmp" && !sort_calls.is_empty();
            if in_sort && !any_test(&stack) {
                self.emit(
                    "float-sort-order",
                    ln,
                    "float ordering via `partial_cmp` in a sort/max/min comparator".to_string(),
                );
            }

            // -- attributes: consume `#[...]`, noting `#[cfg(test)]`
            if txt == "#" && k + 1 < ntoks && self.text(k + 1) == "[" {
                let mut depth = 0i64;
                let mut j = k + 1;
                let mut saw_cfg = false;
                let mut saw_test = false;
                while j < ntoks {
                    let t2 = self.text(j);
                    if t2 == "[" {
                        depth += 1;
                    } else if t2 == "]" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    } else {
                        if t2 == "cfg" {
                            saw_cfg = true;
                        }
                        if t2 == "test" {
                            saw_test = true;
                        }
                    }
                    j += 1;
                }
                if saw_cfg && saw_test {
                    pending_test_attr = true;
                }
                stmt.pop();
                k = j + 1;
                continue;
            }

            // -- condition capture: tokens between if/while/match/for and
            // the opening `{`
            if pending_cond.is_some() {
                if txt == "(" || txt == "[" {
                    cond_paren += 1;
                }
                if txt == ")" || txt == "]" {
                    cond_paren -= 1;
                }
                if txt == "{" && cond_paren <= 0 {
                    let (kind, ctoks) = pending_cond.take().expect("checked");
                    if self.det && !any_test(&stack) {
                        self.scan_cond_header(kind, &ctoks);
                    }
                    let mut why = self.cond_rank_local(&ctoks);
                    if pending_else && why.is_none() {
                        why = last_if_flag.get(&stack.len()).cloned().flatten();
                    }
                    if kind == ScopeKind::If {
                        last_if_flag.insert(stack.len(), why.clone());
                    }
                    stack.push(Scope::with_cond(kind, why));
                    pending_else = false;
                    self.check_stmt(&mut stmt, &stack);
                    k += 1;
                    continue;
                }
                if let Some((_, ctoks)) = pending_cond.as_mut() {
                    ctoks.push(k);
                }
                k += 1;
                continue;
            }

            if isid && matches!(txt.as_str(), "if" | "while" | "match") {
                let kind = match txt.as_str() {
                    "if" => ScopeKind::If,
                    "while" => ScopeKind::While,
                    _ => ScopeKind::Match,
                };
                pending_cond = Some((kind, Vec::new()));
                cond_paren = 0;
                k += 1;
                continue;
            }
            if isid && txt == "for" && !(k > 0 && self.text(k - 1) == ".") {
                // `impl Trait for Type` is not a loop
                let lo = k.saturating_sub(8);
                let impl_back = self.toks[lo..k].iter().any(|t| t.text == "impl");
                if impl_back {
                    k += 1;
                    continue;
                }
                pending_cond = Some((ScopeKind::For, Vec::new()));
                cond_paren = 0;
                k += 1;
                continue;
            }
            if isid && txt == "else" {
                pending_else = true;
                if k + 1 < ntoks && self.text(k + 1) == "{" {
                    let why = last_if_flag.get(&stack.len()).cloned().flatten();
                    stack.push(Scope::with_cond(ScopeKind::Else, why));
                    pending_else = false;
                    self.check_stmt(&mut stmt, &stack);
                    k += 2;
                    continue;
                }
                k += 1;
                continue;
            }
            if isid && txt == "fn" {
                pending_kw = Some(ScopeKind::Fn);
                k += 1;
                continue;
            }
            if isid && txt == "loop" {
                pending_kw = Some(ScopeKind::Loop);
                k += 1;
                continue;
            }
            if isid && txt == "mod" {
                pending_kw = Some(ScopeKind::Mod);
                k += 1;
                continue;
            }
            if isid && txt == "move" {
                pending_kw = Some(ScopeKind::Closure);
                k += 1;
                continue;
            }
            if txt == "|" {
                pending_kw = Some(ScopeKind::Closure);
                k += 1;
                continue;
            }

            if txt == "{" {
                let mut kind = ScopeKind::Block;
                let mut test = false;
                match pending_kw {
                    Some(ScopeKind::Fn) => kind = ScopeKind::Fn,
                    Some(ScopeKind::Closure) => kind = ScopeKind::Closure,
                    Some(ScopeKind::Loop) => kind = ScopeKind::Loop,
                    Some(ScopeKind::Mod) => {
                        kind = ScopeKind::Mod;
                        if pending_test_attr {
                            test = true;
                        }
                    }
                    _ => {}
                }
                if kind == ScopeKind::Mod {
                    pending_test_attr = false;
                }
                let mut sc = Scope::plain(kind);
                sc.test = test;
                stack.push(sc);
                pending_kw = None;
                self.check_stmt(&mut stmt, &stack);
                k += 1;
                continue;
            }
            if txt == "}" {
                if stack.len() > 1 {
                    last_if_flag.remove(&stack.len());
                    stack.pop();
                }
                self.check_stmt(&mut stmt, &stack);
                k += 1;
                continue;
            }
            if txt == ";" {
                self.check_stmt(&mut stmt, &stack);
                k += 1;
                continue;
            }

            let in_test = any_test(&stack);

            // -- R1: collectives under rank-local control flow
            if self.r1_on && !in_test && isid && COLLECTIVES.contains(&txt.as_str()) {
                let dotted = k > 0 && self.text(k - 1) == ".";
                let called = k + 1 < ntoks && self.text(k + 1) == "(";
                if dotted && called {
                    match enclosing_rank_local(&stack) {
                        Some(why) => {
                            let msg = format!(
                                "collective `{txt}` under a rank-local conditional ({why})"
                            );
                            self.emit("collective-divergence", ln, msg);
                        }
                        None => {
                            let fi = innermost_fn_idx(&stack);
                            if stack[fi].divergent_return {
                                let msg = format!(
                                    "collective `{txt}` after a rank-local early return \
                                     in the same function"
                                );
                                self.emit("collective-divergence", ln, msg);
                            }
                        }
                    }
                }
            }
            if isid && txt == "return" && !in_test && enclosing_rank_local(&stack).is_some() {
                let fi = innermost_fn_idx(&stack);
                stack[fi].divergent_return = true;
            }

            // -- R3: determinism hygiene (det-critical modules only)
            if self.det && !in_test && isid {
                self.check_rng(&txt, ln);
                let called = k + 1 < ntoks && self.text(k + 1) == "(";
                self.check_timing_call(&txt, called, ln);
                if txt == "now" && k >= 3 {
                    let a = self.text(k - 1).to_string();
                    let b = self.text(k - 2).to_string();
                    let c = self.text(k - 3).to_string();
                    self.check_clock_now(&a, &b, &c, ln);
                }
                let iter_name =
                    matches!(txt.as_str(), "iter" | "keys" | "values" | "drain" | "into_iter");
                if iter_name && k >= 2 {
                    let mut name: Option<String> = None;
                    {
                        let prev = &self.toks[k - 2];
                        let dotted = self.text(k - 1) == ".";
                        if dotted && prev.is_ident && self.hash_idents.contains(&prev.text) {
                            name = Some(prev.text.clone());
                        }
                    }
                    if let Some(name) = name {
                        let msg = format!("iteration over hash-ordered `{name}`");
                        self.emit("hash-iteration", ln, msg);
                    }
                }
                if txt == "in" && k + 1 < ntoks {
                    let mut j = k + 1;
                    while j < ntoks && matches!(self.text(j), "&" | "mut") {
                        j += 1;
                    }
                    let mut name: Option<String> = None;
                    if j < ntoks {
                        let t2 = &self.toks[j];
                        let next_dot = j + 1 < ntoks && self.text(j + 1) == ".";
                        if t2.is_ident && self.hash_idents.contains(&t2.text) && !next_dot {
                            name = Some(t2.text.clone());
                        }
                    }
                    if let Some(name) = name {
                        let msg = format!("iteration over hash-ordered `{name}`");
                        self.emit("hash-iteration", ln, msg);
                    }
                }
            }

            // -- R4: unsafe accountability (everywhere, tests included)
            if isid && txt == "unsafe" {
                let stmt_start = stmt.first().map(|&i| self.toks[i].line).unwrap_or(ln);
                let mut ok = false;
                for l in stmt_start..=ln {
                    if self.comment_has_safety(l) {
                        ok = true;
                    }
                }
                let mut l = stmt_start.saturating_sub(1);
                while !ok && l > 0 && self.comments.contains_key(&l) && !self.code_lines.contains(&l)
                {
                    if self.comment_has_safety(l) {
                        ok = true;
                    }
                    l -= 1;
                }
                if !ok {
                    self.emit(
                        "unsafe-missing-safety",
                        ln,
                        "`unsafe` without a `// SAFETY:` comment".to_string(),
                    );
                }
            }

            k += 1;
        }
        self.check_stmt(&mut stmt, &stack);
    }

    fn comment_has_safety(&self, l: usize) -> bool {
        match self.comments.get(&l) {
            Some(t) => t.contains("SAFETY:"),
            None => false,
        }
    }
}

/// Scan one file's source. `rel` is the path used for module
/// classification (determinism-critical directories, R1 exemptions) and
/// reported in findings.
pub fn scan_source(rel: &str, src: &str) -> Vec<Finding> {
    let (toks, comments) = lex(src);
    let code_lines: BTreeSet<usize> = toks.iter().map(|t| t.line).collect();
    let det = is_det_critical(rel);
    let norm = rel.replace('\\', "/");
    let r1_on = !R1_EXEMPT_SUFFIX.iter().any(|s| norm.ends_with(s));
    let hash_idents = collect_hash_idents(&toks);
    let mut a = Analyzer {
        rel: rel.to_string(),
        toks,
        comments,
        code_lines,
        det,
        r1_on,
        hash_idents,
        findings: Vec::new(),
    };
    a.run();
    a.findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_skips_strings_and_chars() {
        let src = "let s = \"unsafe { }\"; let c = 'x'; let lt: &'static str = r#\"if rank\"#;";
        let (toks, _) = lex(src);
        assert!(!toks.iter().any(|t| t.text == "unsafe"));
        assert!(!toks.iter().any(|t| t.text == "rank"));
        // lifetimes are consumed without producing tokens
        assert!(!toks.iter().any(|t| t.text == "static"));
        assert!(toks.iter().any(|t| t.text == "str"));
    }

    #[test]
    fn lexer_tuple_index_method() {
        let (toks, _) = lex("a.1.partial_cmp(&b.1)");
        assert!(toks.iter().any(|t| t.text == "partial_cmp"));
    }

    #[test]
    fn lexer_counts_lines_in_block_comments() {
        let (toks, _) = lex("/* a\n b\n c */ fn x() {}\n");
        assert_eq!(toks[0].text, "fn");
        assert_eq!(toks[0].line, 3);
    }

    #[test]
    fn det_critical_paths() {
        assert!(is_det_critical("partition/kmeans.rs"));
        assert!(is_det_critical("src/runtime_sim/mod.rs"));
        assert!(!is_det_critical("util/timer.rs"));
        assert!(!is_det_critical("graph/metrics.rs"));
    }

    #[test]
    fn hash_idents_tracked() {
        let src = "let mut acc: HashMap<u32, f64> = HashMap::new();\nlet v: Vec<HashSet<u32>> = x;";
        let (toks, _) = lex(src);
        let ids = collect_hash_idents(&toks);
        assert!(ids.contains("acc"));
        // `Vec<HashSet<..>>` binds a Vec, not a hash collection
        assert!(!ids.contains("v"));
    }
}
