//! Interprocedural collective-trace inference (detlint v2).
//!
//! Built on the same hand-rolled lexer as the per-file rules: every file
//! is tokenized, every `fn` item is extracted with its impl/trait
//! qualification, and each body is parsed into a small *effect tree* —
//! the ordered sequence of calls, sig-emitting collective markers,
//! early returns, and symbolic `loop{…}` / `branch{a|b}` nodes. Call
//! sites resolve through a crate-wide name index (dotted calls to
//! `&self` methods, free calls to free fns, `Type::`-qualified calls to
//! that impl), and per-function *collective effect signatures* flatten
//! bottom-up through the call graph into [`TraceNode`] sequences.
//!
//! The traces power three rule families:
//!
//! - **R5 `branch-congruence`** — a call that transitively issues
//!   collectives inside a rank-local branch (or after a rank-local
//!   early return) diverges exactly like a direct collective would (R1
//!   only sees direct calls); arms of a *non*-rank-local conditional
//!   must agree on their collective effect (one-sided conditionals are
//!   presumed SPMD-uniform and pass).
//! - **R6 `loop-divergence`** — a loop whose bound reads rank-local
//!   data (`rank`, `is_root`, dotted `len`/`is_empty`) must have an
//!   empty transitive collective effect, or every rank may run a
//!   different number of collective-bearing iterations.
//! - **R7 `epoch-arithmetic`** — raw `fabric.send`/`fabric.recv` tags
//!   must derive from `next_epoch()`/`alloc_tags(n)` (a forward
//!   dataflow over `let` bindings); manual `.epoch` arithmetic outside
//!   `rank.rs` is flagged; and each sig-emitting collective's direct
//!   tag-allocation-site count must match the [`EPOCH_SITES`] table, so
//!   a round-structure change cannot silently drift the tag namespace.
//!
//! `detlint --trace` serializes every public `ctx`-taking entry point's
//! flattened trace as JSON ([`CrateAnalysis::traces_json`]); the
//! runtime test `rust/tests/trace_congruence.rs` replays session steps
//! and asserts the fabric's recorded signature sequence is a
//! concretization of the static trace via [`trace_matches`].
//!
//! Known approximations (all conservative for the shipped tree): macro
//! bodies other than `coll_sig!` are skipped, nested `fn` items inside
//! bodies are opaque, trait-object / ambiguous calls flatten to the
//! empty effect, and closure bodies are treated as executing inline at
//! their definition site.

use std::collections::{BTreeMap, BTreeSet};

use crate::{lex, push_checked, Finding, Tok, COLLECTIVES, R1_EXEMPT_SUFFIX};

/// Cap on trace variants kept per effect-list flattening: branches with
/// equal arms dedupe to one variant, so only genuinely divergent code
/// (an R5 finding anyway) approaches this.
const MAX_VARIANTS: usize = 16;

/// Expected direct `next_epoch`/`alloc_tags` call sites per sig-emitting
/// collective in `runtime_sim/collectives.rs` — the documented tag
/// consumption R7 cross-checks against each body. A collective whose
/// round structure changes must update this table in the same commit.
pub const EPOCH_SITES: &[(&str, usize)] = &[
    ("barrier", 0),
    ("broadcast_bytes", 1),
    ("reduce_f64", 1),
    ("allreduce_f64", 1),
    ("allreduce_multi", 2),
    ("allreduce_u64", 2),
    ("exscan_f64", 1),
    ("exscan_u64_many", 1),
    ("gather_bytes", 1),
    ("allgather_bytes", 0),
    ("alltoallv_rounds", 1),
    ("reduce_scatter_f64", 1),
];

/// One node of a flattened collective trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceNode {
    /// A collective issued here, named after the sig-emitting
    /// collective fn (`barrier`, `allreduce_u64`, …).
    Coll(String),
    /// Zero or more repetitions of the body.
    Loop(Vec<TraceNode>),
    /// Exactly one of the alternative sequences.
    Alt(Vec<Vec<TraceNode>>),
}

/// A public `ctx`-taking entry point and its flattened trace.
#[derive(Debug, Clone, PartialEq)]
pub struct EntryTrace {
    pub file: String,
    pub line: usize,
    /// `Type::name` for methods, bare `name` for free fns.
    pub name: String,
    pub trace: Vec<TraceNode>,
}

/// Crate-wide analysis result: R5–R7 findings plus per-entry traces.
pub struct CrateAnalysis {
    findings: Vec<Finding>,
    entries: Vec<EntryTrace>,
}

impl CrateAnalysis {
    pub fn findings(&self) -> &[Finding] {
        &self.findings
    }

    pub fn into_findings(self) -> Vec<Finding> {
        self.findings
    }

    pub fn entry_traces(&self) -> &[EntryTrace] {
        &self.entries
    }

    /// Look up an entry trace by qualified name (`DistSession::repartition`).
    pub fn entry_trace(&self, name: &str) -> Option<&EntryTrace> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Deterministic JSON for `--trace` / `traces.lock`. Line numbers
    /// are deliberately omitted so unrelated edits don't churn the lock
    /// file — only a *trace* change fails the CI diff.
    pub fn traces_json(&self) -> String {
        let mut out = String::from("{\n  \"entries\": [");
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": {}, \"file\": {}, \"trace\": {}}}",
                crate::json_str(&e.name),
                crate::json_str(&e.file),
                trace_json(&e.trace),
            ));
        }
        if !self.entries.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }
}

fn trace_json(trace: &[TraceNode]) -> String {
    let parts: Vec<String> = trace.iter().map(node_json).collect();
    format!("[{}]", parts.join(", "))
}

fn node_json(n: &TraceNode) -> String {
    match n {
        TraceNode::Coll(s) => crate::json_str(s),
        TraceNode::Loop(b) => format!("{{\"loop\": {}}}", trace_json(b)),
        TraceNode::Alt(arms) => {
            let parts: Vec<String> = arms.iter().map(|a| trace_json(a)).collect();
            format!("{{\"alt\": [{}]}}", parts.join(", "))
        }
    }
}

/// Compact human rendering of a trace, for findings and diagnostics.
pub fn trace_str(trace: &[TraceNode]) -> String {
    let parts: Vec<String> = trace.iter().map(node_str).collect();
    parts.join(", ")
}

fn node_str(n: &TraceNode) -> String {
    match n {
        TraceNode::Coll(s) => s.clone(),
        TraceNode::Loop(b) => format!("loop{{{}}}", trace_str(b)),
        TraceNode::Alt(arms) => {
            let parts: Vec<String> = arms.iter().map(|a| trace_str(a)).collect();
            format!("alt{{{}}}", parts.join(" | "))
        }
    }
}

/// The collective name of a runtime signature: the prefix before the
/// first `(` (`"allreduce_u64(op=Sum, lanes=3)"` → `"allreduce_u64"`).
pub fn sig_name(sig: &str) -> &str {
    sig.split('(').next().unwrap_or(sig)
}

/// Does the runtime signature sequence `seq` concretize the symbolic
/// trace? Position-set (NFA) simulation: `Loop` closes under repeated
/// body matches, `Alt` unions its arms; polynomial and total.
pub fn trace_matches(trace: &[TraceNode], seq: &[String]) -> bool {
    let mut start = BTreeSet::new();
    start.insert(0usize);
    let end = match_from(trace, seq, &start);
    end.contains(&seq.len())
}

fn match_from(nodes: &[TraceNode], seq: &[String], pos: &BTreeSet<usize>) -> BTreeSet<usize> {
    let mut cur = pos.clone();
    for node in nodes {
        if cur.is_empty() {
            break;
        }
        cur = match_step(node, seq, &cur);
    }
    cur
}

fn match_step(node: &TraceNode, seq: &[String], pos: &BTreeSet<usize>) -> BTreeSet<usize> {
    match node {
        TraceNode::Coll(name) => pos
            .iter()
            .filter(|&&p| p < seq.len() && sig_name(&seq[p]) == name)
            .map(|&p| p + 1)
            .collect(),
        TraceNode::Alt(arms) => {
            let mut out = BTreeSet::new();
            for arm in arms {
                out.extend(match_from(arm, seq, pos));
            }
            out
        }
        TraceNode::Loop(body) => {
            // zero-or-more: monotone fixpoint over reachable positions
            let mut acc = pos.clone();
            let mut frontier = pos.clone();
            loop {
                let next = match_from(body, seq, &frontier);
                let fresh: BTreeSet<usize> = next.difference(&acc).copied().collect();
                if fresh.is_empty() {
                    break;
                }
                acc.extend(fresh.iter().copied());
                frontier = fresh;
            }
            acc
        }
    }
}

// ---------------------------------------------------------------------------
// Effect extraction
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CallKind {
    /// `.name(` — resolves to `&self` methods.
    Dotted,
    /// `name(` — resolves to free fns.
    Free,
    /// `Qual::name(` — resolves within `Qual`'s impl (or falls back to
    /// free fns for module-path calls).
    Qualified,
}

#[derive(Debug, Clone)]
enum Effect {
    /// A `coll_sig!` / `check_collective` marker: the enclosing fn *is*
    /// a sig-emitting collective named after itself.
    SigSelf { line: usize },
    Call { name: String, qual: Option<String>, kind: CallKind, line: usize },
    Return { line: usize },
    Loop { why: Option<String>, line: usize, body: Vec<Effect> },
    Branch { why: Option<String>, line: usize, arms: Vec<Vec<Effect>> },
}

struct FnInfo {
    rel: String,
    name: String,
    /// Impl/trait type this fn is defined on, if any.
    qual: Option<String>,
    line: usize,
    is_pub: bool,
    has_self: bool,
    has_ctx: bool,
    in_test: bool,
    body: Vec<Effect>,
    /// Token range of the body, for the R7 token-level scans.
    body_span: (usize, usize),
    /// Pattern idents bound in the signature (tag-derivation seeds).
    params: Vec<String>,
}

struct FileData {
    rel: String,
    toks: Vec<Tok>,
    comments: BTreeMap<usize, String>,
    code_lines: BTreeSet<usize>,
    /// Indices into the crate-wide fn table.
    fn_ids: Vec<usize>,
}

/// Skip an attribute starting at the `[` at `k`; returns the index just
/// past the closing `]` and whether the attribute mentions `test`.
fn skip_attr(toks: &[Tok], k: usize) -> (usize, bool) {
    let mut d = 0i64;
    let mut j = k;
    let mut has_test = false;
    while j < toks.len() {
        let t = &toks[j];
        if t.text == "[" {
            d += 1;
        } else if t.text == "]" {
            d -= 1;
            if d == 0 {
                return (j + 1, has_test);
            }
        } else if t.is_ident && t.text == "test" {
            has_test = true;
        }
        j += 1;
    }
    (j, has_test)
}

/// Rank-local markers in a captured condition/bound: `rank`, `is_root`,
/// or a dotted `len()`/`is_empty()` read (same markers as R1).
fn rank_local(toks: &[Tok], idxs: &[usize]) -> Option<String> {
    for &i in idxs {
        let t = &toks[i];
        if !t.is_ident {
            continue;
        }
        match t.text.as_str() {
            "rank" => return Some("reads `rank`".to_string()),
            "is_root" => return Some("calls `is_root()`".to_string()),
            "len" | "is_empty" if i > 0 && toks[i - 1].text == "." => {
                return Some(format!("reads a rank-local `{}()`", t.text));
            }
            _ => {}
        }
    }
    None
}

const NOT_CALLS: &[&str] = &[
    "if", "else", "while", "for", "match", "return", "loop", "fn", "in", "as", "move", "let",
    "break", "continue", "where", "impl", "dyn", "unsafe",
];

fn call_at(toks: &[Tok], i: usize) -> Effect {
    let name = toks[i].text.clone();
    let line = toks[i].line;
    let (kind, qual) = if i >= 1 && toks[i - 1].text == "." {
        (CallKind::Dotted, None)
    } else if i >= 2 && toks[i - 1].text == ":" && toks[i - 2].text == ":" {
        let q = if i >= 3 && toks[i - 3].is_ident { Some(toks[i - 3].text.clone()) } else { None };
        (CallKind::Qualified, q)
    } else {
        (CallKind::Free, None)
    };
    Effect::Call { name, qual, kind, line }
}

/// Flat effect scan over captured tokens (condition headers, expression
/// match arms): calls and sig markers in order, `return` appended last.
fn scan_flat(toks: &[Tok], idxs: &[usize], with_return: bool) -> Vec<Effect> {
    let mut out = Vec::new();
    let mut ret: Option<usize> = None;
    for &i in idxs {
        let t = &toks[i];
        if !t.is_ident {
            continue;
        }
        let next = toks.get(i + 1).map_or("", |t| t.text.as_str());
        if t.text == "coll_sig" && next == "!" {
            out.push(Effect::SigSelf { line: t.line });
            continue;
        }
        if t.text == "check_collective" && next == "(" {
            out.push(Effect::SigSelf { line: t.line });
            continue;
        }
        if t.text == "return" {
            ret = Some(t.line);
            continue;
        }
        if next == "(" && !NOT_CALLS.contains(&t.text.as_str()) {
            out.push(call_at(toks, i));
        }
    }
    if with_return {
        if let Some(line) = ret {
            out.push(Effect::Return { line });
        }
    }
    out
}

/// Recursive-descent effect parser over a fn body's token stream.
struct BodyParser<'a> {
    toks: &'a [Tok],
    k: usize,
}

impl BodyParser<'_> {
    fn text(&self, i: usize) -> &str {
        self.toks.get(i).map_or("", |t| t.text.as_str())
    }

    fn line(&self, i: usize) -> usize {
        self.toks.get(i).map_or(0, |t| t.line)
    }

    fn is_ident(&self, i: usize) -> bool {
        self.toks.get(i).is_some_and(|t| t.is_ident)
    }

    /// Parse from just past a `{` through its matching `}`.
    fn parse_block(&mut self) -> Vec<Effect> {
        let mut out = Vec::new();
        let mut pending_return: Option<usize> = None;
        while self.k < self.toks.len() {
            let txt = self.text(self.k).to_string();
            let isid = self.is_ident(self.k);
            match txt.as_str() {
                "}" => {
                    if let Some(l) = pending_return.take() {
                        out.push(Effect::Return { line: l });
                    }
                    self.k += 1;
                    return out;
                }
                "{" => {
                    self.k += 1;
                    out.extend(self.parse_block());
                }
                ";" => {
                    if let Some(l) = pending_return.take() {
                        out.push(Effect::Return { line: l });
                    }
                    self.k += 1;
                }
                "#" if self.text(self.k + 1) == "[" => {
                    self.k = skip_attr(self.toks, self.k + 1).0;
                }
                "if" if isid => {
                    let effs = self.parse_if();
                    out.extend(effs);
                }
                "while" if isid => {
                    let e = self.parse_while();
                    out.push(e);
                }
                "for" if isid => {
                    let effs = self.parse_for();
                    out.extend(effs);
                }
                "loop" if isid => {
                    let e = self.parse_loop();
                    out.push(e);
                }
                "match" if isid => {
                    let effs = self.parse_match();
                    out.extend(effs);
                }
                "return" if isid => {
                    pending_return = Some(self.line(self.k));
                    self.k += 1;
                }
                "fn" if isid && self.is_ident(self.k + 1) => {
                    self.skip_nested_fn();
                }
                _ => {
                    if isid && self.text(self.k + 1) == "!" && txt != "coll_sig" {
                        // macro invocation: opaque
                        self.k += 2;
                        self.skip_balanced_if_delim();
                    } else if isid && (txt == "coll_sig" || txt == "check_collective") {
                        out.push(Effect::SigSelf { line: self.line(self.k) });
                        self.k += 1;
                        if self.text(self.k) == "!" {
                            self.k += 1;
                            self.skip_balanced_if_delim();
                        }
                    } else if isid
                        && self.text(self.k + 1) == "("
                        && !NOT_CALLS.contains(&txt.as_str())
                    {
                        out.push(call_at(self.toks, self.k));
                        self.k += 1;
                    } else {
                        self.k += 1;
                    }
                }
            }
        }
        if let Some(l) = pending_return.take() {
            out.push(Effect::Return { line: l });
        }
        out
    }

    /// Capture condition tokens up to the block `{`, consuming it. An
    /// `if let`/`while let` pattern (which may contain `{`) is skipped
    /// up to its depth-0 `=` first.
    fn capture_cond_header(&mut self) -> Vec<usize> {
        let mut hdr = Vec::new();
        if self.text(self.k) == "let" && self.is_ident(self.k) {
            self.k += 1;
            let mut d = 0i64;
            while self.k < self.toks.len() {
                match self.text(self.k) {
                    "(" | "[" | "{" => d += 1,
                    ")" | "]" | "}" => d -= 1,
                    "=" if d == 0 && self.text(self.k + 1) != "=" => {
                        self.k += 1;
                        break;
                    }
                    _ => {}
                }
                self.k += 1;
            }
        }
        let mut d = 0i64;
        while self.k < self.toks.len() {
            match self.text(self.k) {
                "(" | "[" => d += 1,
                ")" | "]" => d -= 1,
                "{" => {
                    if d <= 0 {
                        self.k += 1;
                        return hdr;
                    }
                    d += 1;
                }
                "}" => d -= 1,
                _ => {}
            }
            hdr.push(self.k);
            self.k += 1;
        }
        hdr
    }

    fn parse_if(&mut self) -> Vec<Effect> {
        let line = self.line(self.k);
        self.k += 1;
        let hdr = self.capture_cond_header();
        let why = rank_local(self.toks, &hdr);
        // the condition is evaluated by every rank before the split
        let mut out = scan_flat(self.toks, &hdr, false);
        let arm1 = self.parse_block();
        let mut arms = vec![arm1];
        if self.text(self.k) == "else" && self.is_ident(self.k) {
            self.k += 1;
            if self.text(self.k) == "if" && self.is_ident(self.k) {
                arms.push(self.parse_if());
            } else if self.text(self.k) == "{" {
                self.k += 1;
                arms.push(self.parse_block());
            } else {
                arms.push(Vec::new());
            }
        } else {
            arms.push(Vec::new());
        }
        out.push(Effect::Branch { why, line, arms });
        out
    }

    fn parse_while(&mut self) -> Effect {
        let line = self.line(self.k);
        self.k += 1;
        let hdr = self.capture_cond_header();
        let why = rank_local(self.toks, &hdr);
        // the bound is re-evaluated each iteration: header effects live
        // inside the loop
        let mut body = scan_flat(self.toks, &hdr, false);
        body.extend(self.parse_block());
        Effect::Loop { why, line, body }
    }

    fn parse_for(&mut self) -> Vec<Effect> {
        let line = self.line(self.k);
        self.k += 1;
        let hdr = self.capture_cond_header();
        let mut iter_part: &[usize] = &hdr;
        for (w, &i) in hdr.iter().enumerate() {
            if self.toks[i].is_ident && self.toks[i].text == "in" {
                iter_part = &hdr[w + 1..];
                break;
            }
        }
        let why = rank_local(self.toks, iter_part);
        // the iterator expression is evaluated once, before the loop
        let mut out = scan_flat(self.toks, iter_part, false);
        let body = self.parse_block();
        out.push(Effect::Loop { why, line, body });
        out
    }

    fn parse_loop(&mut self) -> Effect {
        let line = self.line(self.k);
        self.k += 1;
        while self.k < self.toks.len() && self.text(self.k) != "{" {
            self.k += 1;
        }
        self.k += 1;
        Effect::Loop { why: None, line, body: self.parse_block() }
    }

    fn parse_match(&mut self) -> Vec<Effect> {
        let line = self.line(self.k);
        self.k += 1;
        let hdr = self.capture_cond_header();
        let why = rank_local(self.toks, &hdr);
        let mut out = scan_flat(self.toks, &hdr, false);
        let mut arms: Vec<Vec<Effect>> = Vec::new();
        loop {
            while self.text(self.k) == "#" && self.text(self.k + 1) == "[" {
                self.k = skip_attr(self.toks, self.k + 1).0;
            }
            if self.k >= self.toks.len() || self.text(self.k) == "}" {
                self.k += 1;
                break;
            }
            // pattern (and guard) up to the depth-0 `=>`
            let mut d = 0i64;
            let mut found_arrow = false;
            while self.k < self.toks.len() {
                let t = self.text(self.k);
                if d == 0 && t == "=" && self.text(self.k + 1) == ">" {
                    self.k += 2;
                    found_arrow = true;
                    break;
                }
                if d == 0 && t == "}" {
                    break;
                }
                match t {
                    "(" | "[" | "{" => d += 1,
                    ")" | "]" | "}" => d -= 1,
                    _ => {}
                }
                self.k += 1;
            }
            if !found_arrow {
                continue;
            }
            if self.text(self.k) == "{" {
                self.k += 1;
                arms.push(self.parse_block());
                if self.text(self.k) == "," {
                    self.k += 1;
                }
            } else {
                // expression arm: flat scan up to the depth-0 `,` / `}`
                let mut d2 = 0i64;
                let mut expr: Vec<usize> = Vec::new();
                while self.k < self.toks.len() {
                    let t = self.text(self.k);
                    if d2 == 0 && t == "," {
                        self.k += 1;
                        break;
                    }
                    if d2 == 0 && t == "}" {
                        break;
                    }
                    match t {
                        "(" | "[" | "{" => d2 += 1,
                        ")" | "]" | "}" => d2 -= 1,
                        _ => {}
                    }
                    expr.push(self.k);
                    self.k += 1;
                }
                arms.push(scan_flat(self.toks, &expr, true));
            }
        }
        out.push(Effect::Branch { why, line, arms });
        out
    }

    /// Nested `fn` items are opaque: skip the signature and body.
    fn skip_nested_fn(&mut self) {
        self.k += 1;
        let mut d = 0i64;
        while self.k < self.toks.len() {
            match self.text(self.k) {
                "(" | "[" => d += 1,
                ")" | "]" => d -= 1,
                ";" if d == 0 => {
                    self.k += 1;
                    return;
                }
                "{" if d == 0 => break,
                _ => {}
            }
            self.k += 1;
        }
        let mut bd = 0i64;
        while self.k < self.toks.len() {
            match self.text(self.k) {
                "{" => bd += 1,
                "}" => {
                    bd -= 1;
                    if bd == 0 {
                        self.k += 1;
                        return;
                    }
                }
                _ => {}
            }
            self.k += 1;
        }
    }

    fn skip_balanced_if_delim(&mut self) {
        if !matches!(self.text(self.k), "(" | "[" | "{") {
            return;
        }
        let mut d = 0i64;
        while self.k < self.toks.len() {
            match self.text(self.k) {
                "(" | "[" | "{" => d += 1,
                ")" | "]" | "}" => {
                    d -= 1;
                    if d == 0 {
                        self.k += 1;
                        return;
                    }
                }
                _ => {}
            }
            self.k += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Item extraction
// ---------------------------------------------------------------------------

struct ScopeEntry {
    open_depth: i64,
    qual: Option<String>,
    test: bool,
}

/// Extract every bodied `fn` item in one file.
fn extract_fns(rel: &str, toks: &[Tok]) -> Vec<FnInfo> {
    let mut fns = Vec::new();
    let mut depth = 0i64;
    let mut scopes: Vec<ScopeEntry> = Vec::new();
    let mut pending_pub = false;
    let mut pending_test = false;
    let n = toks.len();
    let mut k = 0usize;
    while k < n {
        let t = &toks[k];
        let txt = t.text.as_str();
        match txt {
            "#" if k + 1 < n && toks[k + 1].text == "[" => {
                let (j, has_test) = skip_attr(toks, k + 1);
                if has_test {
                    pending_test = true;
                }
                k = j;
            }
            "{" => {
                depth += 1;
                pending_pub = false;
                k += 1;
            }
            "}" => {
                depth -= 1;
                while scopes.last().is_some_and(|s| s.open_depth > depth) {
                    scopes.pop();
                }
                pending_pub = false;
                pending_test = false;
                k += 1;
            }
            ";" => {
                pending_pub = false;
                pending_test = false;
                k += 1;
            }
            "pub" if t.is_ident => {
                pending_pub = true;
                k += 1;
            }
            "impl" | "trait" if t.is_ident => {
                let (qual, next) = parse_impl_header(toks, k + 1, txt == "trait");
                depth += 1;
                scopes.push(ScopeEntry { open_depth: depth, qual, test: false });
                pending_pub = false;
                pending_test = false;
                k = next;
            }
            "mod" if t.is_ident => {
                if k + 2 < n && toks[k + 2].text == "{" {
                    depth += 1;
                    let inherited = scopes.iter().any(|s| s.test);
                    scopes.push(ScopeEntry {
                        open_depth: depth,
                        qual: None,
                        test: pending_test || inherited,
                    });
                    k += 3;
                } else {
                    k += 2;
                }
                pending_pub = false;
                pending_test = false;
            }
            "fn" if t.is_ident && k + 1 < n && toks[k + 1].is_ident => {
                let in_test = pending_test || scopes.iter().any(|s| s.test);
                let qual = scopes.iter().rev().find_map(|s| s.qual.clone());
                if let Some((info, next)) =
                    parse_fn(rel, toks, k, pending_pub, in_test, qual)
                {
                    fns.push(info);
                    k = next;
                } else {
                    k += 1;
                }
                pending_pub = false;
                pending_test = false;
            }
            _ => {
                k += 1;
            }
        }
    }
    fns
}

/// Parse an `impl`/`trait` header starting just past the keyword:
/// returns the impl type (the last angle-depth-0 path ident, after
/// `for` if present) and the index just past the opening `{`.
fn parse_impl_header(toks: &[Tok], mut k: usize, is_trait: bool) -> (Option<String>, usize) {
    let mut angle = 0i64;
    let mut best: Option<String> = None;
    let mut stopped = false;
    while k < toks.len() && toks[k].text != "{" {
        let t = &toks[k];
        match t.text.as_str() {
            "<" => angle += 1,
            ">" => {
                if k == 0 || toks[k - 1].text != "-" {
                    angle -= 1;
                }
            }
            "where" if t.is_ident && angle == 0 => stopped = true,
            "for" if t.is_ident && angle == 0 && !is_trait && !stopped => best = None,
            _ => {
                if t.is_ident
                    && angle == 0
                    && !stopped
                    && !matches!(t.text.as_str(), "mut" | "dyn" | "const" | "unsafe")
                {
                    best = Some(t.text.clone());
                }
            }
        }
        k += 1;
    }
    (best, k + 1)
}

/// Parse one `fn` item starting at the `fn` keyword. Returns `None` for
/// bodiless declarations (trait method signatures).
fn parse_fn(
    rel: &str,
    toks: &[Tok],
    k: usize,
    is_pub: bool,
    in_test: bool,
    qual: Option<String>,
) -> Option<(FnInfo, usize)> {
    let n = toks.len();
    let name = toks[k + 1].text.clone();
    let line = toks[k + 1].line;
    let mut j = k + 2;
    // generics: `>` preceded by `-` is a return arrow inside `Fn(..) -> R`
    if j < n && toks[j].text == "<" {
        let mut angle = 1i64;
        j += 1;
        while j < n && angle > 0 {
            match toks[j].text.as_str() {
                "<" => angle += 1,
                ">" => {
                    if toks[j - 1].text != "-" {
                        angle -= 1;
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }
    while j < n && toks[j].text != "(" {
        j += 1;
    }
    // parameter list (outermost parens excluded, nested ones kept
    // balanced so `param_names` can track depth)
    let mut pd = 0i64;
    let mut param_toks: Vec<usize> = Vec::new();
    while j < n {
        match toks[j].text.as_str() {
            "(" => {
                pd += 1;
                if pd >= 2 {
                    param_toks.push(j);
                }
            }
            ")" => {
                pd -= 1;
                if pd == 0 {
                    j += 1;
                    break;
                }
                param_toks.push(j);
            }
            _ => {
                if pd >= 1 {
                    param_toks.push(j);
                }
            }
        }
        j += 1;
    }
    let has_self = param_toks.iter().any(|&i| toks[i].is_ident && toks[i].text == "self");
    let has_ctx = param_toks
        .iter()
        .any(|&i| toks[i].is_ident && (toks[i].text == "ctx" || toks[i].text == "RankCtx"));
    let params = param_names(toks, &param_toks);
    // return type / where clause, then body `{` or bodiless `;`
    let mut d2 = 0i64;
    while j < n {
        match toks[j].text.as_str() {
            "(" | "[" => d2 += 1,
            ")" | "]" => d2 -= 1,
            ";" if d2 == 0 => return None,
            "{" if d2 == 0 => break,
            _ => {}
        }
        j += 1;
    }
    if j >= n {
        return None;
    }
    let body_start = j + 1;
    let mut p = BodyParser { toks, k: body_start };
    let body = p.parse_block();
    let body_end = p.k.saturating_sub(1);
    Some((
        FnInfo {
            rel: rel.to_string(),
            name,
            qual,
            line,
            is_pub,
            has_self,
            has_ctx,
            in_test,
            body,
            body_span: (body_start, body_end),
            params,
        },
        p.k,
    ))
}

/// Pattern idents bound in a parameter list: for each comma-separated
/// parameter, the idents before its `:` (handles `mut x`, tuple
/// patterns; skips `self`).
fn param_names(toks: &[Tok], param_toks: &[usize]) -> Vec<String> {
    let mut out = Vec::new();
    let mut d = 0i64;
    let mut in_ty = false;
    for &i in param_toks {
        let t = &toks[i];
        match t.text.as_str() {
            "(" | "[" | "<" => d += 1,
            // `>` closing a return arrow (`Fn(..) -> R`) is not a generic close
            ">" if i == 0 || toks[i - 1].text != "-" => d -= 1,
            ")" | "]" => d -= 1,
            ":" if d == 0 => in_ty = true,
            "," if d == 0 => in_ty = false,
            _ => {
                if !in_ty && t.is_ident && !matches!(t.text.as_str(), "mut" | "ref" | "self") {
                    out.push(t.text.clone());
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Call resolution + flattening
// ---------------------------------------------------------------------------

struct Flattener<'a> {
    fns: &'a [FnInfo],
    /// name → fn indices (non-test fns only).
    index: &'a BTreeMap<String, Vec<usize>>,
    memo: BTreeMap<usize, Vec<TraceNode>>,
    active: Vec<usize>,
}

impl Flattener<'_> {
    /// Resolve a call site to a fn index: dotted calls to `&self`
    /// methods, free calls to free fns, `Qual::` to that impl (`Self::`
    /// through the caller's impl). Same-file unique match wins, then a
    /// globally unique one; ambiguity resolves to nothing (empty
    /// effect — conservative for traces, silent for rules).
    fn resolve(
        &self,
        caller: usize,
        name: &str,
        qual: Option<&str>,
        kind: CallKind,
    ) -> Option<usize> {
        let cands = self.index.get(name)?;
        let fns = self.fns;
        let caller_rel = &fns[caller].rel;
        let pick = |matched: &[usize]| -> Option<usize> {
            let same: Vec<usize> =
                matched.iter().copied().filter(|&j| &fns[j].rel == caller_rel).collect();
            if same.len() == 1 {
                return Some(same[0]);
            }
            if matched.len() == 1 {
                return Some(matched[0]);
            }
            None
        };
        match kind {
            CallKind::Qualified => {
                let q = match qual {
                    Some("Self") => fns[caller].qual.as_deref(),
                    q => q,
                };
                if let Some(q) = q {
                    let m: Vec<usize> = cands
                        .iter()
                        .copied()
                        .filter(|&j| fns[j].qual.as_deref() == Some(q))
                        .collect();
                    if !m.is_empty() {
                        return pick(&m);
                    }
                }
                // module-path call (`median::distributed_median_bisect`)
                let m: Vec<usize> = cands.iter().copied().filter(|&j| !fns[j].has_self).collect();
                pick(&m)
            }
            CallKind::Dotted => {
                let m: Vec<usize> = cands.iter().copied().filter(|&j| fns[j].has_self).collect();
                pick(&m)
            }
            CallKind::Free => {
                let m: Vec<usize> = cands.iter().copied().filter(|&j| !fns[j].has_self).collect();
                pick(&m)
            }
        }
    }

    /// The callee's flattened trace for one call effect, if it resolves.
    fn call_trace(
        &mut self,
        caller: usize,
        name: &str,
        qual: Option<&str>,
        kind: CallKind,
    ) -> Option<Vec<TraceNode>> {
        let j = self.resolve(caller, name, qual, kind)?;
        Some(self.flat_fn(j))
    }

    /// Flatten one fn to its trace; memoized, cycles cut to empty.
    fn flat_fn(&mut self, i: usize) -> Vec<TraceNode> {
        if let Some(t) = self.memo.get(&i) {
            return t.clone();
        }
        if self.active.contains(&i) {
            return Vec::new();
        }
        self.active.push(i);
        let fns = self.fns;
        let body: &[Effect] = &fns[i].body;
        let vars = self.flat_list(body, i);
        let mut traces: Vec<Vec<TraceNode>> = Vec::new();
        for (t, _) in vars {
            if !traces.contains(&t) {
                traces.push(t);
            }
        }
        let trace = if traces.len() == 1 {
            traces.remove(0)
        } else {
            vec![TraceNode::Alt(traces)]
        };
        self.active.pop();
        self.memo.insert(i, trace.clone());
        trace
    }

    /// Flatten an effect list to its distinct trace variants, each
    /// tagged with whether it ends in a `return` (continuation-aware:
    /// a returning branch arm drops the rest of the sequence).
    fn flat_list(&mut self, effects: &[Effect], me: usize) -> Vec<(Vec<TraceNode>, bool)> {
        let Some(head) = effects.first() else {
            return vec![(Vec::new(), false)];
        };
        let rest = &effects[1..];
        match head {
            Effect::SigSelf { .. } => {
                let pre = vec![TraceNode::Coll(self.fns[me].name.clone())];
                prepend(pre, self.flat_list(rest, me))
            }
            Effect::Call { name, qual, kind, .. } => {
                let pre = self.call_trace(me, name, qual.as_deref(), *kind).unwrap_or_default();
                prepend(pre, self.flat_list(rest, me))
            }
            Effect::Return { .. } => vec![(Vec::new(), true)],
            Effect::Loop { body, .. } => {
                let body_vars = self.flat_list(body, me);
                let mut traces: Vec<Vec<TraceNode>> = Vec::new();
                for (t, _) in body_vars {
                    if !t.is_empty() && !traces.contains(&t) {
                        traces.push(t);
                    }
                }
                let pre: Vec<TraceNode> = if traces.is_empty() {
                    Vec::new()
                } else if traces.len() == 1 {
                    vec![TraceNode::Loop(traces.remove(0))]
                } else {
                    vec![TraceNode::Loop(vec![TraceNode::Alt(traces)])]
                };
                prepend(pre, self.flat_list(rest, me))
            }
            Effect::Branch { arms, .. } => {
                let rest_vars = self.flat_list(rest, me);
                let mut out: Vec<(Vec<TraceNode>, bool)> = Vec::new();
                for arm in arms {
                    for (at, ret) in self.flat_list(arm, me) {
                        if ret {
                            push_unique(&mut out, (at, true));
                        } else {
                            for (rt, rret) in &rest_vars {
                                let mut t = at.clone();
                                t.extend(rt.iter().cloned());
                                push_unique(&mut out, (t, *rret));
                            }
                        }
                        if out.len() >= MAX_VARIANTS {
                            break;
                        }
                    }
                }
                out
            }
        }
    }
}

fn prepend(
    pre: Vec<TraceNode>,
    vars: Vec<(Vec<TraceNode>, bool)>,
) -> Vec<(Vec<TraceNode>, bool)> {
    if pre.is_empty() {
        return vars;
    }
    let mut out: Vec<(Vec<TraceNode>, bool)> = Vec::new();
    for (t, r) in vars {
        let mut nt = pre.clone();
        nt.extend(t);
        push_unique(&mut out, (nt, r));
    }
    out
}

fn push_unique(out: &mut Vec<(Vec<TraceNode>, bool)>, item: (Vec<TraceNode>, bool)) {
    if !out.contains(&item) {
        out.push(item);
    }
}

/// Does this trace actually contain a collective anywhere?
pub fn has_coll(trace: &[TraceNode]) -> bool {
    trace.iter().any(|n| match n {
        TraceNode::Coll(_) => true,
        TraceNode::Loop(b) => has_coll(b),
        TraceNode::Alt(arms) => arms.iter().any(|a| has_coll(a)),
    })
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// A direct dotted collective call is R1's domain; R5/R6 only report
/// *transitive* effects so nothing is double-flagged.
fn is_direct_collective(name: &str, kind: CallKind) -> bool {
    kind == CallKind::Dotted && COLLECTIVES.contains(&name)
}

fn effects_have_transitive_coll(fl: &mut Flattener, me: usize, effects: &[Effect]) -> bool {
    effects.iter().any(|e| match e {
        Effect::SigSelf { .. } => true,
        Effect::Call { name, qual, kind, .. } => {
            !is_direct_collective(name, *kind)
                && fl
                    .call_trace(me, name, qual.as_deref(), *kind)
                    .is_some_and(|t| has_coll(&t))
        }
        Effect::Return { .. } => false,
        Effect::Loop { body, .. } => effects_have_transitive_coll(fl, me, body),
        Effect::Branch { arms, .. } => {
            arms.iter().any(|a| effects_have_transitive_coll(fl, me, a))
        }
    })
}

/// R5/R6 walk over one fn's effect tree. `rank_ctx` carries the
/// innermost rank-local branch condition; `divergent` goes sticky once
/// a rank-local arm returns early.
#[allow(clippy::too_many_arguments)]
fn walk_rules(
    fl: &mut Flattener,
    me: usize,
    effects: &[Effect],
    rank_ctx: Option<&str>,
    divergent: &mut Option<String>,
    raw: &mut Vec<(&'static str, usize, String)>,
) {
    for e in effects {
        match e {
            Effect::SigSelf { .. } => {}
            Effect::Call { name, qual, kind, line } => {
                if is_direct_collective(name, *kind) {
                    continue;
                }
                let effectful = fl
                    .call_trace(me, name, qual.as_deref(), *kind)
                    .filter(|t| has_coll(t));
                let Some(t) = effectful else { continue };
                if let Some(w) = rank_ctx {
                    raw.push((
                        "branch-congruence",
                        *line,
                        format!(
                            "`{name}` transitively issues collectives ({}) inside a \
                             rank-local branch ({w})",
                            trace_str(&t)
                        ),
                    ));
                } else if let Some(w) = divergent.as_ref() {
                    raw.push((
                        "branch-congruence",
                        *line,
                        format!(
                            "`{name}` transitively issues collectives ({}) after a \
                             rank-local early return ({w})",
                            trace_str(&t)
                        ),
                    ));
                }
            }
            Effect::Return { .. } => {
                if let Some(w) = rank_ctx {
                    if divergent.is_none() {
                        *divergent = Some(w.to_string());
                    }
                }
            }
            Effect::Loop { why, line, body } => {
                if let Some(w) = why {
                    if effects_have_transitive_coll(fl, me, body) {
                        raw.push((
                            "loop-divergence",
                            *line,
                            format!(
                                "loop with a rank-local bound ({w}) has a non-empty \
                                 transitive collective effect"
                            ),
                        ));
                    }
                }
                walk_rules(fl, me, body, rank_ctx, divergent, raw);
            }
            Effect::Branch { why, line, arms } => {
                match why {
                    Some(w) => {
                        for arm in arms {
                            walk_rules(fl, me, arm, Some(w.as_str()), divergent, raw);
                        }
                    }
                    None => {
                        // arm congruence: distinct non-empty arm effects
                        let mut distinct: Vec<Vec<TraceNode>> = Vec::new();
                        for arm in arms {
                            let vars = fl.flat_list(arm, me);
                            let mut traces: Vec<Vec<TraceNode>> = Vec::new();
                            for (t, _) in vars {
                                if !traces.contains(&t) {
                                    traces.push(t);
                                }
                            }
                            let t = if traces.len() == 1 {
                                traces.remove(0)
                            } else {
                                vec![TraceNode::Alt(traces)]
                            };
                            if has_coll(&t) && !distinct.contains(&t) {
                                distinct.push(t);
                            }
                        }
                        if distinct.len() >= 2 {
                            raw.push((
                                "branch-congruence",
                                *line,
                                format!(
                                    "conditional arms have divergent collective effects \
                                     ({} vs {})",
                                    trace_str(&distinct[0]),
                                    trace_str(&distinct[1])
                                ),
                            ));
                        }
                        for arm in arms {
                            walk_rules(fl, me, arm, rank_ctx, divergent, raw);
                        }
                    }
                }
            }
        }
    }
}

/// R7a: tag-derivation dataflow over one fn body. `derived` starts from
/// the signature's pattern idents; every `let` whose RHS mentions
/// `next_epoch`/`alloc_tags` or an already-derived ident extends it;
/// every raw `fabric.send`/`fabric.recv` must pass a derived tag.
fn r7_tag_flow(f: &FnInfo, toks: &[Tok], raw: &mut Vec<(&'static str, usize, String)>) {
    let (lo, hi) = f.body_span;
    let mut derived: BTreeSet<String> = f.params.iter().cloned().collect();
    derived.insert("next_epoch".to_string());
    derived.insert("alloc_tags".to_string());
    let mut k = lo;
    while k < hi {
        if toks[k].is_ident && toks[k].text == "let" {
            // LHS pattern idents up to the depth-0 `=`
            let mut names: Vec<String> = Vec::new();
            let mut d = 0i64;
            let mut j = k + 1;
            let mut eq: Option<usize> = None;
            while j < hi {
                let t = &toks[j];
                match t.text.as_str() {
                    "(" | "[" | "{" => d += 1,
                    ")" | "]" | "}" => d -= 1,
                    "=" if d == 0 && toks.get(j + 1).is_some_and(|t| t.text != "=") => {
                        eq = Some(j);
                        break;
                    }
                    ";" if d == 0 => break,
                    _ => {
                        if t.is_ident && !matches!(t.text.as_str(), "mut" | "ref") {
                            names.push(t.text.clone());
                        }
                    }
                }
                j += 1;
            }
            if let Some(e) = eq {
                let mut d2 = 0i64;
                let mut m = e + 1;
                let mut hit = false;
                while m < hi {
                    let t = &toks[m];
                    match t.text.as_str() {
                        "(" | "[" | "{" => d2 += 1,
                        ")" | "]" | "}" => d2 -= 1,
                        ";" if d2 == 0 => break,
                        _ => {}
                    }
                    if t.is_ident && derived.contains(&t.text) {
                        hit = true;
                    }
                    m += 1;
                }
                if hit {
                    for n in names {
                        derived.insert(n);
                    }
                }
                k = m;
                continue;
            }
        }
        let is_sendrecv = toks[k].is_ident
            && matches!(toks[k].text.as_str(), "send" | "recv")
            && k >= 2
            && toks[k - 1].text == "."
            && toks[k - 2].is_ident
            && toks[k - 2].text == "fabric"
            && toks.get(k + 1).is_some_and(|t| t.text == "(");
        if is_sendrecv {
            let what = toks[k].text.clone();
            let line = toks[k].line;
            // tag = argument index 2 of fabric.send(src, dst, tag, ..) /
            // fabric.recv(rank, src, tag)
            let mut d = 0i64;
            let mut arg = 0usize;
            let mut ok = false;
            let mut any_ident = false;
            let mut j = k + 1;
            while j < toks.len() {
                let t = &toks[j];
                match t.text.as_str() {
                    "(" | "[" | "{" => {
                        d += 1;
                        j += 1;
                        continue;
                    }
                    ")" | "]" | "}" => {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                        j += 1;
                        continue;
                    }
                    "," if d == 1 => {
                        arg += 1;
                        j += 1;
                        continue;
                    }
                    _ => {}
                }
                if arg == 2 && t.is_ident {
                    any_ident = true;
                    if derived.contains(&t.text) {
                        ok = true;
                    }
                }
                j += 1;
            }
            if !ok {
                let how = if any_ident {
                    "is not derived from `next_epoch`/`alloc_tags`"
                } else {
                    "is a literal"
                };
                raw.push((
                    "epoch-arithmetic",
                    line,
                    format!("`fabric.{what}` tag {how}"),
                ));
            }
        }
        k += 1;
    }
}

/// R7b: manual `.epoch` arithmetic (`+=`, `-=`, `=`) outside `rank.rs`.
fn r7_manual_epoch(f: &FnInfo, toks: &[Tok], raw: &mut Vec<(&'static str, usize, String)>) {
    let (lo, hi) = f.body_span;
    for k in lo..hi {
        let t = &toks[k];
        if !(t.is_ident && t.text == "epoch" && k >= 1 && toks[k - 1].text == ".") {
            continue;
        }
        let n1 = toks.get(k + 1).map_or("", |t| t.text.as_str());
        let n2 = toks.get(k + 2).map_or("", |t| t.text.as_str());
        let assigns = ((n1 == "+" || n1 == "-") && n2 == "=") || (n1 == "=" && n2 != "=");
        if assigns {
            raw.push((
                "epoch-arithmetic",
                t.line,
                "manual `.epoch` arithmetic outside `rank.rs` — tags must go through \
                 `next_epoch()`/`alloc_tags(n)`"
                    .to_string(),
            ));
        }
    }
}

/// R7c: in `runtime_sim/collectives.rs`, each collective's direct
/// tag-allocation call count must match the EPOCH_SITES table.
fn r7_epoch_sites(f: &FnInfo, raw: &mut Vec<(&'static str, usize, String)>) {
    fn count_allocs(effects: &[Effect]) -> usize {
        effects
            .iter()
            .map(|e| match e {
                Effect::Call { name, .. } if name == "next_epoch" || name == "alloc_tags" => 1,
                Effect::Loop { body, .. } => count_allocs(body),
                Effect::Branch { arms, .. } => arms.iter().map(|a| count_allocs(a)).sum(),
                _ => 0,
            })
            .sum()
    }
    let got = count_allocs(&f.body);
    let documented = EPOCH_SITES.iter().find(|(n, _)| *n == f.name).map(|&(_, c)| c);
    match documented {
        Some(want) if want != got => {
            raw.push((
                "epoch-arithmetic",
                f.line,
                format!(
                    "collective `{}` has {got} direct tag-allocation site(s); EPOCH_SITES \
                     documents {want} — update the table with the round-structure change",
                    f.name
                ),
            ));
        }
        None if got > 0 && f.body.iter().any(|e| matches!(e, Effect::SigSelf { .. })) => {
            raw.push((
                "epoch-arithmetic",
                f.line,
                format!(
                    "collective `{}` allocates tags but has no EPOCH_SITES entry",
                    f.name
                ),
            ));
        }
        _ => {}
    }
}

// ---------------------------------------------------------------------------
// Crate analysis driver
// ---------------------------------------------------------------------------

fn ends_with_any(rel: &str, suffixes: &[&str]) -> bool {
    let norm = rel.replace('\\', "/");
    suffixes.iter().any(|s| norm.ends_with(s))
}

/// Analyze a whole file set: `(rel_path, source)` pairs, as produced by
/// [`crate::read_tree`]. Returns R5–R7 findings and per-entry traces.
pub fn analyze_files(files: &[(String, String)]) -> CrateAnalysis {
    let mut file_data: Vec<FileData> = Vec::new();
    let mut fns: Vec<FnInfo> = Vec::new();
    for (rel, src) in files {
        let (toks, comments) = lex(src);
        let code_lines: BTreeSet<usize> = toks.iter().map(|t| t.line).collect();
        let extracted = extract_fns(rel, &toks);
        let base = fns.len();
        let fn_ids: Vec<usize> = (base..base + extracted.len()).collect();
        fns.extend(extracted);
        file_data.push(FileData { rel: rel.clone(), toks, comments, code_lines, fn_ids });
    }

    let mut index: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (i, f) in fns.iter().enumerate() {
        if !f.in_test {
            index.entry(f.name.clone()).or_default().push(i);
        }
    }

    let mut fl = Flattener { fns: &fns, index: &index, memo: BTreeMap::new(), active: Vec::new() };

    let mut findings: Vec<Finding> = Vec::new();
    for fd in &file_data {
        let exempt_r56 = ends_with_any(&fd.rel, R1_EXEMPT_SUFFIX);
        let is_collectives = fd.rel.replace('\\', "/").ends_with("runtime_sim/collectives.rs");
        let exempt_r7ab = ends_with_any(&fd.rel, &["fabric.rs", "rank.rs"]);
        let mut raw: Vec<(&'static str, usize, String)> = Vec::new();
        for &fi in &fd.fn_ids {
            let f = &fns[fi];
            if f.in_test {
                continue;
            }
            if !exempt_r56 {
                let mut divergent: Option<String> = None;
                walk_rules(&mut fl, fi, &f.body, None, &mut divergent, &mut raw);
            }
            if !exempt_r7ab {
                r7_tag_flow(f, &fd.toks, &mut raw);
                r7_manual_epoch(f, &fd.toks, &mut raw);
            }
            if is_collectives {
                r7_epoch_sites(f, &mut raw);
            }
        }
        raw.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
        raw.dedup();
        for (rule, line, msg) in raw {
            push_checked(&mut findings, &fd.comments, &fd.code_lines, &fd.rel, rule, line, msg);
        }
    }

    // entry traces: public ctx-taking fns, in (file, line) order
    let mut entries: Vec<EntryTrace> = Vec::new();
    for (i, f) in fns.iter().enumerate() {
        if !(f.is_pub && f.has_ctx && !f.in_test) {
            continue;
        }
        let trace = fl.flat_fn(i);
        let name = match &f.qual {
            Some(q) => format!("{q}::{}", f.name),
            None => f.name.clone(),
        };
        entries.push(EntryTrace { file: f.rel.clone(), line: f.line, name, trace });
    }
    entries.sort_by(|a, b| (&a.name, &a.file, a.line).cmp(&(&b.name, &b.file, b.line)));

    CrateAnalysis { findings, entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal resolution target: a collective implementation whose
    /// `coll_sig!` marks the fabric slot, so helpers calling it flatten
    /// to a non-empty trace (mirrors `runtime_sim/collectives.rs`).
    const COLL_STUB: (&str, &str) = (
        "runtime_sim/collectives.rs",
        r#"impl RankCtx {
    pub fn allreduce_f64(&mut self, op: ReduceOp, lanes: &[f64]) -> Vec<f64> {
        let _tag = self.next_epoch();
        coll_sig!(self, "allreduce_f64");
        lanes.to_vec()
    }
}
"#,
    );

    fn analyze(files: &[(&str, &str)]) -> CrateAnalysis {
        let owned: Vec<(String, String)> =
            files.iter().map(|(r, s)| (r.to_string(), s.to_string())).collect();
        analyze_files(&owned)
    }

    fn coll(s: &str) -> TraceNode {
        TraceNode::Coll(s.to_string())
    }

    fn sigs(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn transitive_collective_in_rank_branch_is_flagged() {
        let src = r#"fn helper(ctx: &mut RankCtx) {
    ctx.allreduce_f64(ReduceOp::Sum, &[1.0]);
}

pub fn entry(ctx: &mut RankCtx) {
    if ctx.rank == 0 {
        helper(ctx);
    }
}
"#;
        let a = analyze(&[COLL_STUB, ("partition/a.rs", src)]);
        let f = a.findings();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("branch-congruence", 7), "{f:?}");
    }

    #[test]
    fn early_return_makes_later_collectives_divergent() {
        let src = r#"fn helper(ctx: &mut RankCtx) {
    ctx.allreduce_f64(ReduceOp::Sum, &[1.0]);
}

pub fn entry(ctx: &mut RankCtx) {
    if ctx.is_root() {
        return;
    }
    helper(ctx);
}
"#;
        let a = analyze(&[COLL_STUB, ("partition/a.rs", src)]);
        let f = a.findings();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("branch-congruence", 9), "{f:?}");
        // The returning arm drops the continuation: one empty variant,
        // one with the collective.
        let e = a.entry_trace("entry").expect("entry trace");
        assert_eq!(trace_str(&e.trace), "alt{ | allreduce_f64}");
    }

    #[test]
    fn uniform_branch_and_bound_are_clean() {
        let src = r#"fn helper(ctx: &mut RankCtx) {
    ctx.allreduce_f64(ReduceOp::Sum, &[1.0]);
}

pub fn entry(ctx: &mut RankCtx, n_ranks: usize) {
    for _r in 0..n_ranks {
        helper(ctx);
    }
    if n_ranks > 1 {
        helper(ctx);
    }
}
"#;
        let a = analyze(&[COLL_STUB, ("partition/a.rs", src)]);
        assert!(a.findings().is_empty(), "{:?}", a.findings());
        let e = a.entry_trace("entry").expect("entry trace");
        assert_eq!(
            trace_str(&e.trace),
            "alt{loop{allreduce_f64}, allreduce_f64 | loop{allreduce_f64}}"
        );
    }

    #[test]
    fn rank_local_loop_bound_with_collective_body_is_flagged() {
        let src = r#"fn helper(ctx: &mut RankCtx) {
    ctx.allreduce_f64(ReduceOp::Sum, &[1.0]);
}

pub fn entry(ctx: &mut RankCtx, local: &[f64]) {
    for _i in 0..local.len() {
        helper(ctx);
    }
}
"#;
        let a = analyze(&[COLL_STUB, ("partition/a.rs", src)]);
        let f = a.findings();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("loop-divergence", 6), "{f:?}");
    }

    #[test]
    fn derived_tag_chain_is_clean_literal_tag_is_not() {
        let good = r#"pub fn probe(ctx: &mut RankCtx, fabric: &Fabric, dst: usize) {
    let base = ctx.alloc_tags(4);
    let t = base + 1;
    fabric.send(0, dst, t, Vec::new());
}
"#;
        let a = analyze(&[("partition/a.rs", good)]);
        assert!(a.findings().is_empty(), "{:?}", a.findings());
        let bad = r#"pub fn probe(ctx: &mut RankCtx, fabric: &Fabric, dst: usize) {
    fabric.send(0, dst, 7, Vec::new());
}
"#;
        let a = analyze(&[("partition/a.rs", bad)]);
        let f = a.findings();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("epoch-arithmetic", 2), "{f:?}");
    }

    #[test]
    fn trace_matches_loop_and_alt_semantics() {
        // loop{a}, b — the loop matches zero or more repetitions.
        let t = vec![TraceNode::Loop(vec![coll("a")]), coll("b")];
        assert!(trace_matches(&t, &sigs(&["b"])));
        assert!(trace_matches(&t, &sigs(&["a", "b"])));
        assert!(trace_matches(&t, &sigs(&["a", "a", "a", "b"])));
        assert!(!trace_matches(&t, &sigs(&["a"])));
        assert!(!trace_matches(&t, &sigs(&["b", "a"])));
        // alt{x | } — either the arm or nothing.
        let t = vec![TraceNode::Alt(vec![vec![coll("x")], vec![]])];
        assert!(trace_matches(&t, &sigs(&["x"])));
        assert!(trace_matches(&t, &sigs(&[])));
        assert!(!trace_matches(&t, &sigs(&["y"])));
        // Runtime signatures carry their argument rendering.
        let t = vec![coll("allreduce_u64")];
        assert!(trace_matches(&t, &sigs(&["allreduce_u64(op=Sum, lanes=3)"])));
    }

    #[test]
    fn sig_name_strips_argument_rendering() {
        assert_eq!(sig_name("allreduce_u64(op=Sum, lanes=3)"), "allreduce_u64");
        assert_eq!(sig_name("barrier"), "barrier");
    }

    #[test]
    fn traces_json_is_stable_and_line_free() {
        let src = r#"pub fn entry(ctx: &mut RankCtx) {
    ctx.allreduce_f64(ReduceOp::Sum, &[1.0]);
}
"#;
        let a = analyze(&[COLL_STUB, ("partition/a.rs", src)]);
        let json = a.traces_json();
        assert_eq!(
            json,
            r#"{
  "entries": [
    {"name": "entry", "file": "partition/a.rs", "trace": ["allreduce_f64"]}
  ]
}
"#
        );
    }
}
