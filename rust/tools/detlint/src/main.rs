//! detlint CLI — scan Rust sources for SPMD determinism and
//! collective-discipline violations.
//!
//! Usage: `cargo run -p detlint -- [FLAGS] [PATH ...]` (default
//! `rust/src`). Exits non-zero when any finding is reported, so CI can
//! gate on it.
//!
//! Flags:
//! * `--format human|json` — finding output format (default `human`;
//!   the JSON schema is `[{file, line, rule, msg, hint}]`).
//! * `--trace` — print the interprocedural collective traces of every
//!   public `ctx`-taking entry point as JSON and exit 0. CI uploads
//!   this and diffs it against the committed `traces.lock`.
//! * `--bless` — rewrite `tools/detlint/traces.lock` with the traces of
//!   the current tree (run after an intentional collective-structure
//!   change), then report findings as usual.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use detlint::{analyze_files, findings_json, hint_for, scan_source, Finding};

/// Collect `.rs` files under `root`, sorted for deterministic output.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return;
    }
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut children: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    children.sort();
    for child in children {
        if child.is_dir() {
            collect_rs(&child, out);
        } else if child.extension().is_some_and(|e| e == "rs") {
            out.push(child);
        }
    }
}

struct Opts {
    roots: Vec<PathBuf>,
    trace: bool,
    bless: bool,
    json: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts { roots: Vec::new(), trace: false, bless: false, json: false };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--trace" => opts.trace = true,
            "--bless" => opts.bless = true,
            "--format" => match args.next().as_deref() {
                Some("human") => opts.json = false,
                Some("json") => opts.json = true,
                other => return Err(format!("--format wants human|json, got {other:?}")),
            },
            "--format=human" => opts.json = false,
            "--format=json" => opts.json = true,
            _ if a.starts_with("--") => return Err(format!("unknown flag {a}")),
            _ => opts.roots.push(PathBuf::from(a)),
        }
    }
    if opts.roots.is_empty() {
        opts.roots.push(PathBuf::from("rust/src"));
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(msg) => {
            eprintln!("detlint: {msg}");
            return ExitCode::from(2);
        }
    };

    // Gather the whole file set first: the interprocedural pass needs
    // every file to resolve cross-file calls.
    let mut files: Vec<(String, String)> = Vec::new();
    for root in &opts.roots {
        if !root.exists() {
            eprintln!("detlint: path not found: {}", root.display());
            return ExitCode::from(2);
        }
        let mut paths = Vec::new();
        collect_rs(root, &mut paths);
        for file in &paths {
            let src = match std::fs::read_to_string(file) {
                Ok(s) => s,
                Err(err) => {
                    eprintln!("detlint: cannot read {}: {err}", file.display());
                    return ExitCode::from(2);
                }
            };
            // Report paths relative to the scan root when possible.
            let rel = match file.strip_prefix(root) {
                Ok(r) if !r.as_os_str().is_empty() => r.display().to_string(),
                _ => file.display().to_string(),
            };
            files.push((rel, src));
        }
    }
    let scanned = files.len();

    let analysis = analyze_files(&files);

    if opts.bless {
        let lock = concat!(env!("CARGO_MANIFEST_DIR"), "/traces.lock");
        if let Err(err) = std::fs::write(lock, analysis.traces_json()) {
            eprintln!("detlint: cannot write {lock}: {err}");
            return ExitCode::from(2);
        }
        eprintln!(
            "detlint: blessed {} entry trace(s) into {lock}",
            analysis.entry_traces().len()
        );
    }
    if opts.trace {
        print!("{}", analysis.traces_json());
        return ExitCode::SUCCESS;
    }

    // Per-file rules (R1–R4) plus the crate-wide pass (R5–R7).
    let mut findings: Vec<Finding> = Vec::new();
    for (rel, src) in &files {
        findings.extend(scan_source(rel, src));
    }
    findings.extend(analysis.into_findings());
    findings
        .sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));

    if opts.json {
        print!("{}", findings_json(&findings));
    } else {
        for f in &findings {
            println!("{}:{} [{}] {}", f.file, f.line, f.rule, f.msg);
            println!("  hint: {}", hint_for(f.rule));
        }
        println!("detlint: {scanned} files scanned, {} finding(s)", findings.len());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
