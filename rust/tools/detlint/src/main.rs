//! detlint CLI — scan Rust sources for SPMD determinism and
//! collective-discipline violations.
//!
//! Usage: `cargo run -p detlint -- [PATH ...]` (default `rust/src`).
//! Exits non-zero when any finding is reported, so CI can gate on it.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use detlint::{hint_for, scan_source, Finding};

/// Collect `.rs` files under `root`, sorted for deterministic output.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return;
    }
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut children: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    children.sort();
    for child in children {
        if child.is_dir() {
            collect_rs(&child, out);
        } else if child.extension().is_some_and(|e| e == "rs") {
            out.push(child);
        }
    }
}

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = std::env::args().skip(1).map(PathBuf::from).collect();
    if roots.is_empty() {
        roots.push(PathBuf::from("rust/src"));
    }

    let mut findings: Vec<Finding> = Vec::new();
    let mut scanned = 0usize;
    for root in &roots {
        if !root.exists() {
            eprintln!("detlint: path not found: {}", root.display());
            return ExitCode::from(2);
        }
        let mut files = Vec::new();
        collect_rs(root, &mut files);
        for file in &files {
            let src = match std::fs::read_to_string(file) {
                Ok(s) => s,
                Err(err) => {
                    eprintln!("detlint: cannot read {}: {err}", file.display());
                    return ExitCode::from(2);
                }
            };
            // Report paths relative to the scan root when possible.
            let rel = match file.strip_prefix(root) {
                Ok(r) if !r.as_os_str().is_empty() => r.display().to_string(),
                _ => file.display().to_string(),
            };
            scanned += 1;
            findings.extend(scan_source(&rel, &src));
        }
    }

    findings.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    for f in &findings {
        println!("{}:{} [{}] {}", f.file, f.line, f.rule, f.msg);
        println!("  hint: {}", hint_for(f.rule));
    }
    if findings.is_empty() {
        println!("detlint: {scanned} files scanned, 0 findings");
        ExitCode::SUCCESS
    } else {
        println!("detlint: {scanned} files scanned, {} finding(s)", findings.len());
        ExitCode::from(1)
    }
}
