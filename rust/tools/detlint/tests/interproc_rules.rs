//! Interprocedural fixture tests: each R5/R6/R7 fixture produces
//! exactly its intended finding, the clean fixtures stay clean, and the
//! `--format json` schema is stable.

use std::path::Path;

use detlint::{analyze_files, findings_json, read_tree, trace_str, Finding};

fn fixture_analysis() -> Vec<Finding> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let files = read_tree(&root).expect("read fixtures tree");
    analyze_files(&files).into_findings()
}

fn on_file<'a>(findings: &'a [Finding], rel: &str) -> Vec<&'a Finding> {
    findings.iter().filter(|f| f.file == rel).collect()
}

fn assert_single(findings: &[Finding], rel: &str, rule: &str, line: usize) {
    let fs = on_file(findings, rel);
    assert_eq!(fs.len(), 1, "{rel}: expected exactly one finding, got {fs:?}");
    assert_eq!(fs[0].rule, rule, "{rel}: wrong rule: {fs:?}");
    assert_eq!(fs[0].line, line, "{rel}: wrong line: {fs:?}");
}

#[test]
fn r5_transitive_collective_in_rank_local_branch() {
    assert_single(&fixture_analysis(), "partition/r5_bad.rs", "branch-congruence", 13);
}

#[test]
fn r5_transitive_collective_after_rank_local_early_return() {
    assert_single(&fixture_analysis(), "partition/r5_early_return.rs", "branch-congruence", 13);
}

#[test]
fn r5_divergent_collective_effects_across_arms() {
    let findings = fixture_analysis();
    assert_single(&findings, "partition/r5_arms.rs", "branch-congruence", 14);
    let fs = on_file(&findings, "partition/r5_arms.rs");
    assert!(
        fs[0].msg.contains("allreduce_f64") && fs[0].msg.contains("allreduce_u64"),
        "message should name both arm traces: {fs:?}"
    );
}

#[test]
fn r6_collective_loop_with_rank_local_bound() {
    assert_single(&fixture_analysis(), "partition/r6_bad.rs", "loop-divergence", 11);
}

#[test]
fn r7_manual_epoch_arithmetic() {
    assert_single(&fixture_analysis(), "partition/r7_manual_epoch.rs", "epoch-arithmetic", 5);
}

#[test]
fn r7_literal_point_to_point_tag() {
    assert_single(&fixture_analysis(), "partition/r7_tag_literal.rs", "epoch-arithmetic", 6);
}

#[test]
fn r7_epoch_sites_mismatch() {
    let findings = fixture_analysis();
    assert_single(&findings, "runtime_sim/collectives.rs", "epoch-arithmetic", 11);
    let fs = on_file(&findings, "runtime_sim/collectives.rs");
    assert!(fs[0].msg.contains("EPOCH_SITES"), "{fs:?}");
}

#[test]
fn clean_fixtures_have_no_interproc_findings() {
    let findings = fixture_analysis();
    for rel in [
        "partition/interproc_clean.rs",
        "partition/clean.rs",
        // Direct collectives under rank-local control flow are R1's
        // domain (scan_source); the interprocedural pass must not
        // double-report them.
        "partition/r1_bad.rs",
        "partition/r1_early_return.rs",
    ] {
        let fs = on_file(&findings, rel);
        assert!(fs.is_empty(), "{rel}: unexpected interproc findings {fs:?}");
    }
}

#[test]
fn fixture_entry_traces_flatten_through_helpers() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let files = read_tree(&root).expect("read fixtures tree");
    let analysis = analyze_files(&files);
    let t = |name: &str| {
        trace_str(&analysis.entry_trace(name).unwrap_or_else(|| panic!("entry {name}")).trace)
    };
    assert_eq!(t("mismatched"), "alt{allreduce_f64 | allreduce_u64}");
    assert_eq!(t("per_point"), "loop{allreduce_f64}");
    assert_eq!(t("skips_root"), "alt{ | allreduce_f64}");
}

/// Quote a hint string the way the lint's JSON writer does (hints carry
/// no control characters, so escaping `\` and `"` suffices).
fn json_quote(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

#[test]
fn findings_json_schema_is_stable() {
    let findings = vec![Finding {
        file: "partition/a.rs".to_string(),
        line: 7,
        rule: "branch-congruence",
        msg: "a \"quoted\" message".to_string(),
    }];
    let json = findings_json(&findings);
    let expected = format!(
        "[\n  {{\"file\": \"partition/a.rs\", \"line\": 7, \"rule\": \"branch-congruence\", \
         \"msg\": \"a \\\"quoted\\\" message\", \"hint\": {}}}\n]\n",
        // the hint rides along verbatim; its wording is free to evolve
        json_quote(detlint::hint_for("branch-congruence")),
    );
    assert_eq!(json, expected);
}
