//! Fixture: R7 EPOCH_SITES — `barrier` is documented as allocating zero
//! dedicated tag blocks (it rides the congruence slot), but this version
//! bumps the epoch anyway: exactly one mismatch finding.
//!
//! The compliant stubs below double as the resolution targets for the
//! R5/R6 fixtures' transitive-collective helpers: `coll_sig!` marks the
//! fabric slot, so flattening a helper that calls them yields a
//! non-empty collective trace.

impl RankCtx {
    pub fn barrier(&mut self) {
        let _tag = self.next_epoch();
    }

    pub fn allreduce_f64(&mut self, op: ReduceOp, lanes: &[f64]) -> Vec<f64> {
        let tag = self.next_epoch();
        coll_sig!(self, "allreduce_f64(op={op:?}, lanes={})", lanes.len());
        let _ = tag;
        lanes.to_vec()
    }

    pub fn allreduce_u64(&mut self, op: ReduceOp, lanes: &[u64]) -> Vec<u64> {
        let tag = self.next_epoch();
        coll_sig!(self, "allreduce_u64(op={op:?}, lanes={})", lanes.len());
        let tag2 = self.next_epoch();
        let _ = (tag, tag2);
        lanes.to_vec()
    }
}
