//! Fixture: R3 float-sort-order — a float sort via `partial_cmp`. Must
//! fire exactly once.

pub fn order_by_weight(ws: &mut Vec<(u32, f64)>) {
    ws.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
}
