//! Fixture: R1 collective-divergence — a rank-local early return followed
//! by a collective later in the same function. Must fire exactly once.

pub fn early_out(ctx: &mut RankCtx, local: &[f64]) -> f64 {
    if local.is_empty() {
        return 0.0;
    }
    let s: f64 = local.iter().sum();
    ctx.allreduce_f64(ReduceOp::Sum, &[s])[0]
}
