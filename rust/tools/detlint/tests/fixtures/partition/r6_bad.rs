//! Fixture: R6 loop-divergence — the loop bound is the local point
//! count, so ranks run different iteration counts, and each iteration
//! transitively issues a collective.

fn sum_all(ctx: &mut RankCtx, s: f64) -> f64 {
    ctx.allreduce_f64(ReduceOp::Sum, &[s])[0]
}

pub fn per_point(ctx: &mut RankCtx, local: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..local.len() {
        acc += sum_all(ctx, local[i]);
    }
    acc
}
