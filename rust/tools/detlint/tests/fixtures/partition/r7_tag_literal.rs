//! Fixture: R7 epoch-arithmetic — a raw `fabric.send` with a literal
//! tag bypasses the epoch allocator; a colliding tag from another phase
//! silently cross-matches messages.

pub fn leak(ctx: &mut RankCtx, fabric: &Fabric, dst: usize, payload: Vec<u8>) {
    fabric.send(0, dst, 42, payload);
}
