//! Fixture: R3 hash-iteration — iterating a HashMap in a
//! determinism-critical module. Must fire exactly once.

use std::collections::HashMap;

pub fn unstable_order(weights: &[f64]) -> Vec<(u32, f64)> {
    let mut acc: HashMap<u32, f64> = HashMap::new();
    for (i, w) in weights.iter().enumerate() {
        *acc.entry(i as u32 % 16).or_insert(0.0) += w;
    }
    let mut out = Vec::new();
    for (k, v) in acc.iter() {
        out.push((*k, *v));
    }
    out
}
