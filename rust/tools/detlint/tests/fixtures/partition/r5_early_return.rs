//! Fixture: R5 branch-congruence — a rank-local early return makes every
//! later transitive collective unreachable for some ranks: the remaining
//! ranks block in `sum_all`'s allreduce forever.

fn sum_all(ctx: &mut RankCtx, s: f64) -> f64 {
    ctx.allreduce_f64(ReduceOp::Sum, &[s])[0]
}

pub fn skips_root(ctx: &mut RankCtx, local: &[f64]) -> f64 {
    if ctx.rank == 0 {
        return 0.0;
    }
    sum_all(ctx, local.iter().sum())
}
