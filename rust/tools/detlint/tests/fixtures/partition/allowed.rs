//! Fixture: allow annotations — each finding here is suppressed by a
//! justified `detlint: allow`, except the last one whose allow has no
//! justification (which must itself be reported).

use std::time::Instant;

pub fn timed_build(xs: &[f64]) -> f64 {
    // detlint: allow(timing-in-compute) -- wall-clock feeds the report row
    // only; the partition result never branches on it.
    let t0 = Instant::now();
    let s: f64 = xs.iter().sum();
    let _elapsed = t0.elapsed();
    s
}

pub fn unjustified(xs: &[f64]) -> f64 {
    // detlint: allow(timing-in-compute)
    let t0 = Instant::now();
    let s: f64 = xs.iter().sum();
    let _elapsed = t0.elapsed();
    s
}
