//! Fixture: R5 branch-congruence — both arms of a data-dependent
//! conditional issue collectives, but *different* ones. Ranks that
//! disagree on `fast_path` present mismatched signatures to the fabric.

fn sum_f64(ctx: &mut RankCtx, s: f64) -> f64 {
    ctx.allreduce_f64(ReduceOp::Sum, &[s])[0]
}

fn sum_u64(ctx: &mut RankCtx, c: u64) -> u64 {
    ctx.allreduce_u64(ReduceOp::Sum, &[c])[0]
}

pub fn mismatched(ctx: &mut RankCtx, fast_path: bool, s: f64, c: u64) -> f64 {
    if fast_path {
        sum_f64(ctx, s)
    } else {
        sum_u64(ctx, c) as f64
    }
}
