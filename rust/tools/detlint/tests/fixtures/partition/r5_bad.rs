//! Fixture: R5 branch-congruence — a helper that transitively issues a
//! collective, called from inside a rank-local branch. R1 only sees
//! direct collective calls; the interprocedural pass must see through
//! `sum_all`.

fn sum_all(ctx: &mut RankCtx, s: f64) -> f64 {
    ctx.allreduce_f64(ReduceOp::Sum, &[s])[0]
}

pub fn divergent(ctx: &mut RankCtx, local: &[f64]) -> f64 {
    let mut acc = 0.0;
    if ctx.rank == 0 {
        acc = sum_all(ctx, local.iter().sum());
    }
    acc
}
