//! Fixture: clean interprocedural code — zero R5–R7 findings expected.
//! Exercises the idioms the rules must NOT flag: uniform conditionals
//! and loop bounds around effectful helpers, and point-to-point tags
//! derived from `next_epoch()`.

fn sum_all(ctx: &mut RankCtx, s: f64) -> f64 {
    ctx.allreduce_f64(ReduceOp::Sum, &[s])[0]
}

pub fn uniform(ctx: &mut RankCtx, n_ranks: usize, local: &[f64]) -> f64 {
    let mut acc = 0.0;
    // uniform bound: every rank loops n_ranks times
    for _r in 0..n_ranks {
        acc += sum_all(ctx, local.first().copied().unwrap_or(0.0));
    }
    // uniform condition with a one-sided collective effect: fine
    if n_ranks > 1 {
        acc = sum_all(ctx, acc);
    }
    acc
}

pub fn ring_probe(ctx: &mut RankCtx, fabric: &Fabric, dst: usize, payload: Vec<u8>) {
    let tag = ctx.next_epoch();
    fabric.send(0, dst, tag, payload);
    let _m = fabric.recv(dst, 0, tag);
}
