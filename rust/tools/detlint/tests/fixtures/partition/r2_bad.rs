//! Fixture: R2 count-lane-f64 — a count cast `as f64` feeding an f64
//! collective lane. Must fire exactly once.

pub fn lossy_count(ctx: &mut RankCtx, local: &[u32]) -> f64 {
    ctx.allreduce_f64(ReduceOp::Sum, &[local.len() as f64])[0]
}
