//! Fixture: R3 unseeded-rng — entropy-seeded RNG in a
//! determinism-critical module. Must fire exactly once.

pub fn jitter(xs: &mut [f64]) {
    let mut rng = rand::thread_rng();
    for x in xs.iter_mut() {
        *x += rng.gen::<f64>() * 1e-9;
    }
}
