//! Fixture: R1 collective-divergence — a collective under a rank-local
//! conditional. Must fire exactly once.

pub fn divergent(ctx: &mut RankCtx, local: &[f64]) -> f64 {
    let mut acc = 0.0;
    if ctx.rank == 0 {
        // only rank 0 issues the collective: the fabric deadlocks
        acc = ctx.allreduce_f64(ReduceOp::Sum, &[local.iter().sum()])[0];
    }
    acc
}
