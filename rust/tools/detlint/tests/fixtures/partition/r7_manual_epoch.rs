//! Fixture: R7 epoch-arithmetic — manual `.epoch` bumps outside
//! `rank.rs` desynchronize the tag allocator across call sites.

pub fn bump(ctx: &mut RankCtx) {
    ctx.epoch += 1;
}
