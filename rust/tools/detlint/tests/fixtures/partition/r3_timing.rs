//! Fixture: R3 timing-in-compute — a clock read inside compute in a
//! determinism-critical module. Must fire exactly once.

use std::time::Instant;

pub fn adaptive_block(xs: &[f64]) -> usize {
    let t0 = Instant::now();
    let mut s = 0.0;
    for x in xs {
        s += x;
    }
    if t0.elapsed().as_micros() > 100 {
        512
    } else {
        4096
    }
}
