//! Fixture: clean determinism-critical code — zero findings expected.
//! Exercises the idioms the rules must NOT flag: uniform conditionals,
//! u64 count lanes, BTreeMap iteration, seeded RNG, total_cmp sorts,
//! documented unsafe.

use std::collections::BTreeMap;

pub fn balanced(ctx: &mut RankCtx, local: &[f64], n_ranks: usize) -> f64 {
    // uniform condition: every rank sees the same n_ranks
    if n_ranks == 1 {
        return local.iter().sum();
    }
    let s: f64 = local.iter().sum();
    // counts ride the exact u64 lane, weights the f64 lane
    let total = ctx.allreduce_multi(&mut [
        Section::F64(ReduceOp::Sum, &mut [s]),
        Section::U64(ReduceOp::Sum, &mut [local.len() as u64]),
    ]);
    total
}

pub fn ordered_output(acc: &BTreeMap<u32, f64>) -> Vec<(u32, f64)> {
    // BTreeMap iteration is key-ordered: deterministic
    acc.iter().map(|(k, v)| (*k, *v)).collect()
}

pub fn det_sort(ws: &mut Vec<(u32, f64)>) {
    ws.sort_by(|a, b| a.1.total_cmp(&b.1));
}

pub fn seeded(seed: u64, xs: &mut [f64]) {
    let mut rng = SplitMix64::new(seed);
    for x in xs.iter_mut() {
        *x = rng.next_f64();
    }
}

pub fn documented(xs: &[u64]) -> u64 {
    // SAFETY: `xs` is non-empty by the caller contract; reading the
    // first element of a valid slice is in-bounds.
    unsafe { *xs.as_ptr() }
}
