//! Fixture: R4 unsafe-missing-safety — an `unsafe` block without a
//! `// SAFETY:` comment. Must fire exactly once (not a determinism-
//! critical path: R4 applies everywhere).

pub fn read_first(xs: &[u64]) -> u64 {
    unsafe { *xs.as_ptr() }
}
