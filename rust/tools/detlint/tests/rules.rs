//! Fixture tests: every rule fires exactly once on its fixture (at the
//! expected line), the clean fixture yields nothing, and `allow`
//! annotations suppress findings only when justified.

use detlint::{scan_source, Finding};

fn scan_fixture(rel: &str) -> Vec<Finding> {
    let path = format!("{}/tests/fixtures/{rel}", env!("CARGO_MANIFEST_DIR"));
    let src = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    scan_source(rel, &src)
}

fn assert_single(rel: &str, rule: &str, line: usize) {
    let fs = scan_fixture(rel);
    assert_eq!(fs.len(), 1, "{rel}: expected exactly one finding, got {fs:?}");
    assert_eq!(fs[0].rule, rule, "{rel}: wrong rule: {fs:?}");
    assert_eq!(fs[0].line, line, "{rel}: wrong line: {fs:?}");
}

#[test]
fn r1_collective_under_rank_conditional() {
    assert_single("partition/r1_bad.rs", "collective-divergence", 8);
}

#[test]
fn r1_collective_after_rank_local_early_return() {
    assert_single("partition/r1_early_return.rs", "collective-divergence", 9);
}

#[test]
fn r2_count_cast_feeding_f64_lane() {
    assert_single("partition/r2_bad.rs", "count-lane-f64", 5);
}

#[test]
fn r3_hash_map_iteration() {
    assert_single("partition/r3_hash_iter.rs", "hash-iteration", 12);
}

#[test]
fn r3_unseeded_rng() {
    assert_single("partition/r3_rng.rs", "unseeded-rng", 5);
}

#[test]
fn r3_wall_clock_in_compute() {
    assert_single("partition/r3_timing.rs", "timing-in-compute", 7);
}

#[test]
fn r3_partial_cmp_in_sort() {
    assert_single("partition/r3_float_sort.rs", "float-sort-order", 5);
}

#[test]
fn r4_undocumented_unsafe_outside_det_dirs() {
    // util/ is not determinism-critical, but R4 applies everywhere.
    assert_single("util/r4_unsafe.rs", "unsafe-missing-safety", 6);
}

#[test]
fn clean_fixture_has_no_findings() {
    let fs = scan_fixture("partition/clean.rs");
    assert!(fs.is_empty(), "clean fixture should be clean: {fs:?}");
}

#[test]
fn justified_allow_suppresses_unjustified_is_reported() {
    let fs = scan_fixture("partition/allowed.rs");
    assert_eq!(fs.len(), 1, "only the unjustified allow should surface: {fs:?}");
    assert_eq!(fs[0].rule, "allow-missing-justification", "{fs:?}");
    assert_eq!(fs[0].line, 18, "{fs:?}");
}

#[test]
fn findings_carry_fix_hints() {
    for f in scan_fixture("partition/r1_bad.rs") {
        assert!(!detlint::hint_for(f.rule).is_empty());
    }
    assert!(!detlint::hint_for("count-lane-f64").is_empty());
    assert!(!detlint::hint_for("no-such-rule").is_empty()); // falls back to generic advice
}

#[test]
fn test_modules_are_exempt_from_r1_to_r3_but_not_r4() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f(ctx: &RankCtx) {\n        if ctx.rank == 0 {\n            ctx.barrier();\n        }\n        let p = unsafe { core::ptr::null::<u8>() };\n        let _ = p;\n    }\n}\n";
    let fs = scan_source("partition/x.rs", src);
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, "unsafe-missing-safety", "{fs:?}");
}
