//! Dynamic workload with amortized load balancing — the paper's §IV
//! "dynamic application with explicit queries": a point database under
//! insert/delete churn, with Adjustments (Algorithm 1) and the credit
//! controller (Algorithm 3) deciding when to rebalance.
//!
//! ```sh
//! cargo run --release --example dynamic_queries -- --points 50000 --iters 1000
//! ```

use sfc_part::cli::Args;
use sfc_part::geom::point::PointSet;
use sfc_part::kdtree::dynamic_driver::run_dynamic;

fn main() {
    let args = Args::parse();
    let n = args.usize("points", 50_000);
    let dim = args.usize("dim", 3);
    let iters = args.usize("iters", 1000);
    let step = args.usize("step", 100);
    let bucket = args.usize("bucket", 32);

    println!("initial dataset: {n} uniform points in {dim}-D, BUCKETSIZE={bucket}");
    println!("running {iters} iterations, insert/delete every {step}, adjustments every {}", 2 * step);

    for threads in args.usize_list("threads", &[1, 2, 4]) {
        let ps = PointSet::uniform(n, dim, args.u64("seed", 7) as u32);
        let s = run_dynamic(&ps, iters, step, threads, bucket, args.u64("seed", 7));
        println!("{s}");
    }
    println!("\ncolumns match Table I: build / ins / del / adj accumulated over the run;");
    println!("'lb' is the time the credit controller chose to spend on full rebalances.");
}
