//! k-NN serving over a clustered point cloud (§V-A / Fig 13): the query
//! router bins and batches queries; candidate windows come from the SFC
//! bucket index; scoring runs through the PJRT `knn_topk` artifact
//! (Pallas distance kernel + top-k) with the scalar path as oracle.
//!
//! ```sh
//! cargo run --release --example point_cloud_knn -- --points 100000 --queries 2000
//! ```

use sfc_part::cli::Args;
use sfc_part::geom::bbox::BoundingBox;
use sfc_part::geom::point::PointSet;
use sfc_part::kdtree::builder::KdTreeBuilder;
use sfc_part::kdtree::splitter::{DimRule, SplitterConfig, SplitterKind};
use sfc_part::query::knn::{knn_exact, knn_sfc, recall};
use sfc_part::query::point_location::BucketIndex;
use sfc_part::runtime::exec::{Engine, KNN_C, KNN_D, KNN_K, KNN_Q};
use sfc_part::sfc::traverse::assign_sfc;
use sfc_part::sfc::Curve;
use sfc_part::util::rng::{Rng, SplitMix64};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.usize("points", 100_000);
    let nq = args.usize("queries", 2000);
    let k = args.usize("knn", 3).min(KNN_K);
    let cutoff = args.usize("cutoff", 1);

    let ps = PointSet::uniform(n, 3, args.u64("seed", 42) as u32);
    let mut cfg = SplitterConfig::uniform(SplitterKind::Midpoint);
    cfg.dim_rule = DimRule::Cycle;
    let sw = sfc_part::util::timer::Stopwatch::start();
    let mut tree = KdTreeBuilder::new().bucket_size(32).splitter(cfg).domain(BoundingBox::unit(3)).threads(4).build(&ps);
    assign_sfc(&mut tree, Curve::Morton);
    let index = BucketIndex::from_tree(&tree, BoundingBox::unit(3));
    println!("indexed {n} points into {} buckets in {:.3}s", index.n_buckets(), sw.secs());

    // Scalar path + recall measurement.
    let mut rng = SplitMix64::new(7);
    let queries: Vec<Vec<f64>> = (0..nq)
        .map(|_| (0..3).map(|_| rng.next_f64()).collect())
        .collect();
    let sw = sfc_part::util::timer::Stopwatch::start();
    let mut results = Vec::with_capacity(nq);
    for q in &queries {
        results.push(knn_sfc(&ps, &index, q, k, cutoff));
    }
    let scalar_secs = sw.secs();
    let mut avg_recall = 0.0;
    for (q, res) in queries.iter().zip(&results).take(50) {
        avg_recall += recall(res, &knn_exact(&ps, q, k));
    }
    println!(
        "scalar knn: {nq} queries in {:.3}s ({:.0} q/s), recall@{k} (50 sampled) = {:.3}",
        scalar_secs,
        nq as f64 / scalar_secs,
        avg_recall / 50.0
    );

    // PJRT path: batch KNN_Q queries against fixed candidate windows.
    match Engine::default_engine() {
        Err(e) => println!("pjrt path skipped: {e}"),
        Ok(engine) => {
            let sw = sfc_part::util::timer::Stopwatch::start();
            let mut served = 0usize;
            let mut agree = 0usize;
            let mut checked = 0usize;
            // Presort queries along the curve (§V-A's binning) so each
            // batch's candidate windows overlap heavily, then batch
            // greedily under the artifact's candidate budget so no
            // query's window is truncated.
            let mut sorted_queries = queries.clone();
            sorted_queries.sort_by_key(|q| {
                sfc_part::sfc::kernel::morton_key_quantized(q, &BoundingBox::unit(3), 30)
            });
            let mut batches: Vec<(Vec<&Vec<f64>>, Vec<u32>)> = Vec::new();
            {
                let mut cur_q: Vec<&Vec<f64>> = Vec::new();
                let mut cur_c: Vec<u32> = Vec::new();
                for q in &sorted_queries {
                    let w = sfc_part::query::knn::candidate_window(&index, q, cutoff);
                    let mut merged = cur_c.clone();
                    merged.extend_from_slice(w);
                    merged.sort_unstable();
                    merged.dedup();
                    if (!cur_q.is_empty() && merged.len() > KNN_C) || cur_q.len() == KNN_Q {
                        batches.push((std::mem::take(&mut cur_q), std::mem::take(&mut cur_c)));
                        cur_c = w.to_vec();
                        cur_c.sort_unstable();
                        cur_c.dedup();
                        cur_c.truncate(KNN_C);
                        cur_q.push(q);
                    } else {
                        cur_q.push(q);
                        cur_c = merged;
                        cur_c.truncate(KNN_C);
                    }
                }
                if !cur_q.is_empty() {
                    batches.push((cur_q, cur_c));
                }
            }
            for (chunk, mut cand) in batches {
                let pad_from = cand.len();
                while cand.len() < KNN_C {
                    cand.push(cand[cand.len() % pad_from.max(1)]);
                }
                let mut qbuf = vec![0.0f32; KNN_Q * KNN_D];
                for (i, q) in chunk.iter().enumerate() {
                    for d in 0..3 {
                        qbuf[i * KNN_D + d] = q[d] as f32;
                    }
                }
                let mut cbuf = vec![0.0f32; KNN_C * KNN_D];
                for (i, &pi) in cand.iter().enumerate() {
                    for d in 0..3 {
                        cbuf[i * KNN_D + d] = ps.coord(pi as usize, d) as f32;
                    }
                }
                let (_dist, idx) = engine.knn_topk(&qbuf, &cbuf)?;
                served += chunk.len();
                // Verify a few against the scalar window result.
                for (i, q) in chunk.iter().enumerate().take(4) {
                    let got: std::collections::HashSet<u32> =
                        idx[i * KNN_K..i * KNN_K + k].iter().map(|&j| cand[j as usize]).collect();
                    let want = knn_sfc(&ps, &index, q, k, cutoff);
                    checked += k;
                    agree += want.iter().filter(|nb| got.contains(&nb.index)).count();
                }
            }
            let secs = sw.secs();
            println!(
                "pjrt knn  : {served} queries in {:.3}s ({:.0} q/s), batch={KNN_Q}, per-window agreement {}/{} (union may find closer)",
                secs,
                served as f64 / secs,
                agree,
                checked
            );
        }
    }
    Ok(())
}
