//! END-TO-END DRIVER (the EXPERIMENTS.md §E2E run): the full §V-B
//! pipeline on a real small workload, proving all layers compose:
//!
//! 1. generate a power-law graph (or load a SNAP file with
//!    `--snap-file`),
//! 2. partition its adjacency nonzeros two ways — row-wise baseline vs
//!    the SFC partitioner (L3 coordinator),
//! 3. run distributed PageRank over simulated ranks, where every rank's
//!    local SpMV executes through the **PJRT block-ELL artifact** (the
//!    L1 Pallas kernel lowered by L2 jax) with a scalar fallback oracle,
//! 4. report the paper's headline metrics (MaxDegree / MaxEdgeCut /
//!    loads) plus latency/throughput of the iteration loop.
//!
//! ```sh
//! cargo run --release --example graph_spmv -- --graph-scale 12 --procs 8 --iters 10
//! ```

use sfc_part::cli::Args;
use sfc_part::graph::metrics::spmv_metrics;
use sfc_part::graph::pagerank::{pagerank_seq, transition_matrix};
use sfc_part::graph::partition2d::{rowwise_partition, sfc_partition};
use sfc_part::graph::spmv_dist::{build_plan, owned_range, spmv_step, LocalMatrix};
use sfc_part::runtime::exec::Engine;
use sfc_part::runtime_sim::{run_ranks, CostModel};
use sfc_part::sfc::Curve;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let scale = args.usize("graph-scale", 12) as u32;
    let p = args.usize("procs", 8);
    let iters = args.usize("iters", 10);
    let damping = 0.85f64;

    // ---- 1. workload ----
    let adj = match args.get("snap-file") {
        Some(path) => sfc_part::graph::snap_io::load_snap(std::path::Path::new(path))?,
        None => sfc_part::graph::rmat::preset("twitter-like", scale, args.u64("seed", 5))
            .unwrap(),
    };
    println!("graph: {} vertices, {} nonzeros", adj.n_rows, adj.nnz());
    let m = transition_matrix(&adj); // the matrix PageRank iterates

    // ---- 2. partitions + metrics ----
    let row_part = rowwise_partition(&m, p);
    let row_m = spmv_metrics(&m, &row_part, p);
    let sw = sfc_part::util::timer::Stopwatch::start();
    let (sfc_part_ids, part_secs) = sfc_partition(&m, p, Curve::HilbertLike, args.usize("threads", 4));
    let _ = sw;
    let sfc_m = spmv_metrics(&m, &sfc_part_ids, p);
    println!("\n            {:>10} {:>10} {:>9} {:>10}", "AvgLoad", "MaxLoad", "MaxDeg", "MaxEdgeCut");
    println!("row-wise    {:>10.0} {:>10} {:>9} {:>10}", row_m.avg_load, row_m.max_load, row_m.max_degree, row_m.max_edgecut);
    println!("sfc         {:>10.0} {:>10} {:>9} {:>10}   (partitioned in {part_secs:.3}s)", sfc_m.avg_load, sfc_m.max_load, sfc_m.max_degree, sfc_m.max_edgecut);

    // ---- 3. distributed PageRank over simulated ranks ----
    // PJRT engine (shared, serialized internally). Falls back to the
    // scalar tile oracle when artifacts are missing.
    let engine = Engine::default_engine().ok();
    if engine.is_some() {
        println!("\nPJRT engine up: local SpMV runs the block-ELL Pallas artifact");
    } else {
        println!("\nartifacts missing (run `make artifacts`); using scalar fallback");
    }
    let n = m.n_rows;
    let run = |part: &Vec<u32>| -> (Vec<f64>, f64, sfc_part::runtime_sim::SimReport) {
        let sw = sfc_part::util::timer::Stopwatch::start();
        let (outs, rep) = run_ranks(p, CostModel::default(), |ctx| {
            let local = LocalMatrix::shard(&m, part, ctx.rank);
            let plan = build_plan(ctx, &local);
            let owned = owned_range(n, p, ctx.rank);
            let len = (owned.1 - owned.0) as usize;
            let mut x = vec![1.0 / n as f64; len];
            for _ in 0..iters {
                let mut y = spmv_step(ctx, &plan, &x);
                // damping + teleport + renormalize (global sum).
                for v in y.iter_mut() {
                    *v = damping * *v + (1.0 - damping) / n as f64;
                }
                let local_sum: f64 = y.iter().sum();
                let total = ctx.allreduce1(sfc_part::runtime_sim::collectives::ReduceOp::Sum, local_sum);
                for v in y.iter_mut() {
                    *v /= total;
                }
                x = y;
            }
            (owned, x)
        });
        let mut full = vec![0.0f64; n];
        for (owned, x) in outs {
            full[owned.0 as usize..owned.1 as usize].copy_from_slice(&x);
        }
        (full, sw.secs(), rep)
    };

    let (pr_sfc, secs_sfc, rep_sfc) = run(&sfc_part_ids);
    let (pr_row, secs_row, rep_row) = run(&row_part);

    // ---- 4. verify + report ----
    let (pr_ref, _) = pagerank_seq(&m.to_csr(), damping, iters, 0.0);
    let err = |x: &Vec<f64>| -> f64 {
        x.iter().zip(&pr_ref).map(|(a, b)| (a - b).abs()).sum()
    };
    println!("\npagerank ({iters} iters, p={p} simulated ranks):");
    println!(
        "  sfc      : wall {:.3}s | sim {:.4}s (compute {:.4}s + net {:.4}s) | msgs {:>8} bytes {:>12} | L1 err vs oracle {:.2e}",
        secs_sfc, rep_sfc.sim_time(), rep_sfc.max_busy(), rep_sfc.net_secs, rep_sfc.total_msgs, rep_sfc.total_bytes, err(&pr_sfc)
    );
    println!(
        "  row-wise : wall {:.3}s | sim {:.4}s (compute {:.4}s + net {:.4}s) | msgs {:>8} bytes {:>12} | L1 err vs oracle {:.2e}",
        secs_row, rep_row.sim_time(), rep_row.max_busy(), rep_row.net_secs, rep_row.total_msgs, rep_row.total_bytes, err(&pr_row)
    );

    // PJRT hot path demo on the full matrix (single-node tile loop).
    if let Some(engine) = &engine {
        let report = sfc_part::runtime::spmv_driver::run_pjrt_spmv(engine, &m, iters)?;
        println!("\n{report}");
    }

    // Headline metrics at the paper's process counts (the separation
    // grows with P; at the small execution p above both fit few peers).
    let p_head = args.usize("headline-procs", 64);
    let row_h = spmv_metrics(&m, &rowwise_partition(&m, p_head), p_head);
    let (sp_h, _) = sfc_partition(&m, p_head, Curve::HilbertLike, args.usize("threads", 4));
    let sfc_h = spmv_metrics(&m, &sp_h, p_head);
    println!(
        "\nheadline @ P={p_head}: MaxLoad {} -> {} ({:.1}x), MaxDegree {} -> {} ({:.1}x), MaxEdgeCut {} -> {} ({:.1}x)",
        row_h.max_load,
        sfc_h.max_load,
        row_h.max_load as f64 / sfc_h.max_load.max(1) as f64,
        row_h.max_degree,
        sfc_h.max_degree,
        row_h.max_degree as f64 / sfc_h.max_degree.max(1) as f64,
        row_h.max_edgecut,
        sfc_h.max_edgecut,
        row_h.max_edgecut as f64 / sfc_h.max_edgecut.max(1) as f64,
    );
    Ok(())
}
