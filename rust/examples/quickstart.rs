//! Quickstart: partition a clustered 3-D point cloud and inspect
//! quality, comparing Morton against the Hilbert-like curve.
//!
//! ```sh
//! cargo run --release --example quickstart -- --points 200000 --parts 16
//! ```

use sfc_part::cli::Args;
use sfc_part::partition::partitioner::{PartitionConfig, Partitioner};
use sfc_part::partition::quality::{surface_to_volume, surface_volume_summary};
use sfc_part::prelude::*;

fn main() {
    let args = Args::parse();
    let n = args.usize("points", 100_000);
    let parts = args.usize("parts", 16);
    let threads = args.usize("threads", 4);

    println!("generating {n} clustered points in 3-D...");
    let ps = PointSet::clustered(n, 3, 0.5, args.u64("seed", 42) as u32);

    for curve in [Curve::Morton, Curve::HilbertLike] {
        let cfg = PartitionConfig {
            parts,
            bucket_size: 32,
            curve,
            threads,
            splitter: sfc_part::kdtree::splitter::SplitterConfig::median_top_midpoint_below(8),
            ..Default::default()
        };
        let plan = Partitioner::new(cfg).partition(&ps);
        let (sv_mean, sv_max) = surface_volume_summary(&surface_to_volume(&ps, &plan.part_of, parts));
        // Curve locality: mean distance between curve-consecutive points.
        let avg_hop: f64 = plan
            .perm
            .windows(2)
            .map(|w| ps.dist2(w[0] as usize, w[1] as usize).sqrt())
            .sum::<f64>()
            / (ps.len() - 1) as f64;
        println!(
            "{curve:>12}: total {:.3}s (build {:.3}s + sfc {:.3}s + knapsack {:.3}s) \
             imbalance {:.5} | avg hop {:.5} | surface/volume mean {:.1} max {:.1}",
            plan.total_secs,
            plan.build_stats.top_secs + plan.build_stats.subtree_secs,
            plan.traverse_stats.secs,
            plan.knapsack_secs,
            plan.imbalance(),
            avg_hop,
            sv_mean,
            sv_max,
        );
    }
    println!("\nboth curves balance to one point weight; the Hilbert-like order has the");
    println!("shorter average hop (better spatial locality along the curve).");
}
