//! Adaptive (Delaunay-style) mesh refinement with load balancing — the
//! paper's original AMR use case (§IV): a refinement front sweeps the
//! mesh, element loads drift, and the partitioner keeps the parts
//! balanced using **incremental** rebalancing (re-rank on the weighted
//! curve, neighbor-limited migration) with the surface-to-volume trigger
//! falling back to a **full** rebalance when partitions grow misshapen.
//!
//! ```sh
//! cargo run --release --example mesh_refinement -- --side 48 --steps 12 --parts 8
//! ```

use sfc_part::cli::Args;
use sfc_part::geom::mesh::{RefinementDriver, SimplexMesh};
use sfc_part::partition::incremental::{
    migration_is_neighbor_limited, needs_full_rebalance, rebalance,
};
use sfc_part::partition::knapsack::{max_load_diff, part_loads};
use sfc_part::partition::partitioner::{PartitionConfig, Partitioner};
use sfc_part::partition::quality::{edge_cut_metrics, surface_to_volume};
use sfc_part::sfc::Curve;

fn main() {
    let args = Args::parse();
    let side = args.usize("side", 48);
    let steps = args.usize("steps", 12);
    let parts = args.usize("parts", 8);

    let mesh = SimplexMesh::unit_square_tri(side);
    let mut drv = RefinementDriver::new(mesh, args.u64("seed", 5));
    println!("initial mesh: {} elements; refining {steps} steps, {parts} parts\n", drv.mesh.n_elems());

    // Initial full partition.
    let cfg = PartitionConfig { parts, curve: Curve::HilbertLike, threads: 4, ..Default::default() };
    let cents = drv.mesh.centroids();
    let (mut plan, _tree) = Partitioner::new(cfg.clone()).partition_with_tree(&cents);
    let mut part_in_order: Vec<u32> =
        plan.perm.iter().map(|&pi| plan.part_of[pi as usize]).collect();
    let mut full_rebalances = 0;
    let mut incremental_rebalances = 0;

    println!(
        "{:>4} {:>8} {:>9} {:>9} {:>10} {:>8} {:>9}",
        "step", "elems", "split", "imbal", "mode", "moved%", "maxcut"
    );
    for step in 0..steps {
        // Alternate topology growth (forces a full rebalance) with pure
        // weight drift over a fixed mesh (incremental's home turf).
        let split = if step % 3 == 0 { drv.step() } else { drv.drift_weights(1.4) };
        let cents = drv.mesh.centroids();

        // Weights in the *existing* curve order for elements that
        // existed; refinement appends children at the end — map them to
        // their parent's curve position neighborhood by a fresh order
        // when the incremental path cannot absorb the growth.
        let grew = cents.len() != plan.perm.len();
        let sv = surface_to_volume(&cents, &remap_parts(&plan, &cents), parts);
        let misshapen = needs_full_rebalance(&sv, 2, 1.0, 4.0);
        if grew || misshapen {
            // Full rebalance (Algorithm 2).
            let (p2, _t) = Partitioner::new(cfg.clone()).partition_with_tree(&cents);
            plan = p2;
            part_in_order = plan.perm.iter().map(|&pi| plan.part_of[pi as usize]).collect();
            full_rebalances += 1;
            let loads = part_loads(&part_in_order, &ordered_weights(&plan, &cents), parts);
            let edges = drv.mesh.dual_edges();
            let (_, maxcut, _) = edge_cut_metrics(&edges, &plan.part_of, parts);
            println!(
                "{:>4} {:>8} {:>9} {:>9.4} {:>10} {:>8} {:>9}",
                step,
                cents.len(),
                split,
                max_load_diff(&loads) / (cents.total_weight() / parts as f64),
                "full",
                "100",
                maxcut
            );
        } else {
            // Incremental: same curve order, new weights.
            let w = ordered_weights(&plan, &cents);
            let rb = rebalance(&part_in_order, &w, parts);
            let moved = rb.moved_weight / cents.total_weight() * 100.0;
            let neighbor = migration_is_neighbor_limited(&rb.moves);
            part_in_order = rb.part_in_order.clone();
            for (pos, &pi) in plan.perm.iter().enumerate() {
                plan.part_of[pi as usize] = rb.part_in_order[pos];
            }
            incremental_rebalances += 1;
            let loads = part_loads(&part_in_order, &w, parts);
            let edges = drv.mesh.dual_edges();
            let (_, maxcut, _) = edge_cut_metrics(&edges, &plan.part_of, parts);
            println!(
                "{:>4} {:>8} {:>9} {:>9.4} {:>10} {:>7.1}{} {:>9}",
                step,
                cents.len(),
                split,
                max_load_diff(&loads) / (cents.total_weight() / parts as f64),
                if neighbor { "incr(nbr)" } else { "incr" },
                moved,
                "%",
                maxcut
            );
        }
    }
    println!(
        "\n{} full + {} incremental rebalances; incremental keeps migration neighbor-local \
         while the front moves slowly.",
        full_rebalances, incremental_rebalances
    );
}

/// Weights of the current mesh in the plan's curve order (valid when the
/// element count is unchanged).
fn ordered_weights(
    plan: &sfc_part::partition::partitioner::PartitionPlan,
    cents: &sfc_part::geom::point::PointSet,
) -> Vec<f32> {
    plan.perm.iter().map(|&pi| cents.weights[pi as usize]).collect()
}

/// Current part of each element under the existing plan (for the
/// surface/volume trigger).
fn remap_parts(
    plan: &sfc_part::partition::partitioner::PartitionPlan,
    cents: &sfc_part::geom::point::PointSet,
) -> Vec<u32> {
    (0..cents.len())
        .map(|i| plan.part_of.get(i).copied().unwrap_or(0))
        .collect()
}
