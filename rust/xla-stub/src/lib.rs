//! Stub of the XLA/PJRT binding surface used by `sfc_part::runtime`.
//!
//! The real crate links the native XLA runtime, which is not available
//! in every build environment. This stub keeps the exact call signatures
//! so the engine layer compiles unchanged; constructing a client fails
//! with a descriptive error, and the callers (engine, CLI, tests) all
//! treat that as "PJRT unavailable" and fall back to the scalar oracles.

/// Error type of every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("XLA/PJRT backend not available: built with the stub `xla` crate".to_string())
}

/// Element types accepted by literals and host buffers.
pub trait NativeType: Copy + Send + Sync + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for u32 {}
impl NativeType for i64 {}
impl NativeType for u64 {}
impl NativeType for u8 {}
impl NativeType for i8 {}

/// A host/device literal. The stub carries no data — no path produces
/// one once client construction fails, but the type must exist for the
/// engine layer's signatures.
#[derive(Debug, Default, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal::default()
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal::default()
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Decompose a 1-tuple literal into its only element.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on literal-like inputs; one output buffer list per device.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }

    /// Execute on device-resident buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client. Always fails in the stub; callers treat the error as
    /// "artifacts/PJRT unavailable" and use their scalar fallbacks.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Platform name of the backing runtime.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    /// Upload a host slice as a device buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
#[derive(Debug, Default)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Default)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_ops_fail_cleanly() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }
}
