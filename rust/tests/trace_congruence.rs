//! Static/dynamic collective-trace cross-check (detlint v2 ↔ fabric).
//!
//! detlint's interprocedural layer infers, per public `ctx`-taking entry
//! point, the symbolic sequence of collectives it issues (with `loop{…}`
//! and `alt{a|b}` nodes for data-dependent control flow). The
//! debug-build fabric records the *actual* signature every rank
//! presented at every collective slot. This test closes the loop: it
//! replays a p=2 session (create + drifting repartition steps), brackets
//! each phase with [`RankCtx::collectives_entered`], and asserts the
//! recorded [`Fabric::coll_signatures`] span of every phase is a
//! concretization of the statically inferred trace via
//! [`detlint::trace_matches`].
//!
//! The two verifiers check each other: a collective added to
//! `repartition` without detlint seeing it (a macro, an unresolvable
//! call) fails here, and a detlint parser regression that drops part of
//! a trace fails here too.

use std::path::Path;

use sfc_part::geom::point::PointSet;
use sfc_part::partition::distributed::{DistSession, SessionConfig};
use sfc_part::partition::partitioner::PartitionConfig;
use sfc_part::partition::scenario::{Scenario, ScenarioKind};
use sfc_part::runtime_sim::{run_ranks_threaded, CostModel};

use detlint::{analyze_files, read_tree, trace_matches, CrateAnalysis};

const P: usize = 2;
const STEPS: usize = 4;

fn static_analysis() -> CrateAnalysis {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let files = read_tree(&src).expect("read rust/src");
    analyze_files(&files)
}

/// Per-rank record: collective-seq spans for create + each step, plus
/// the fabric's recorded signature table (identical across ranks — the
/// table is shared, snapshotted after the last step).
type RankLog = (Vec<(u64, u64)>, Vec<String>);

#[test]
fn runtime_coll_seq_concretizes_static_trace() {
    if !cfg!(debug_assertions) {
        // Release builds record no signatures; the cross-check is a
        // debug-build contract (tier-1 `cargo test` runs debug).
        return;
    }
    let analysis = static_analysis();
    let create_trace = &analysis
        .entry_trace("DistSession::create")
        .expect("static trace for DistSession::create")
        .trace;
    let repart_trace = &analysis
        .entry_trace("DistSession::repartition")
        .expect("static trace for DistSession::repartition")
        .trace;

    let global = PointSet::uniform(2000, 3, 97);
    let cfg = PartitionConfig::default();
    let scenario = Scenario::new(ScenarioKind::Hotspot);

    let (logs, _) = run_ranks_threaded(P, 1, CostModel::default(), |ctx| -> RankLog {
        let local = global.mod_shard(ctx.rank, ctx.n_ranks);
        let mut spans = Vec::with_capacity(STEPS + 1);
        let b = ctx.collectives_entered();
        let mut sess = DistSession::create(ctx, &local, &cfg, 4 * P, SessionConfig::default());
        spans.push((b, ctx.collectives_entered()));
        for step in 0..STEPS {
            let batch = scenario.update_for(sess.local(), step);
            let b = ctx.collectives_entered();
            sess.repartition(ctx, &batch);
            spans.push((b, ctx.collectives_entered()));
        }
        (spans, ctx.fabric.coll_signatures())
    });

    // Both ranks issued identical spans (SPMD discipline), and every
    // recorded slot was entered by both (table length == per-rank seq).
    let (spans0, sigs) = &logs[0];
    for (r, (spans, sigs_r)) in logs.iter().enumerate() {
        assert_eq!(spans, spans0, "rank {r} diverged in collective spans");
        assert_eq!(sigs_r, sigs, "rank {r} snapshotted a different table");
    }
    let last = spans0.last().expect("at least one span").1;
    assert_eq!(sigs.len() as u64, last, "congruence table has holes");

    // Each phase's recorded signature span concretizes its static trace.
    let phase = |i: usize| &sigs[spans0[i].0 as usize..spans0[i].1 as usize];
    assert!(
        trace_matches(create_trace, phase(0)),
        "create: runtime {:?} does not concretize static {:?}",
        phase(0),
        create_trace,
    );
    for step in 0..STEPS {
        let seq = phase(step + 1);
        assert!(
            trace_matches(repart_trace, seq),
            "repartition step {step}: runtime {seq:?} does not concretize static {repart_trace:?}",
        );
        // The trace must also be non-vacuous: every step issues at least
        // the fused refresh + migration collectives.
        assert!(seq.len() >= 3, "repartition step {step} issued only {} collectives", seq.len());
    }
}

/// The static analyzer itself must hold the shipped tree finding-free —
/// the same gate `cargo run -p detlint -- rust/src` enforces in CI, kept
/// here so `cargo test` alone catches a drift.
#[test]
fn shipped_tree_has_no_interproc_findings() {
    let analysis = static_analysis();
    let findings = analysis.findings();
    assert!(
        findings.is_empty(),
        "interprocedural findings on the shipped tree:\n{}",
        findings
            .iter()
            .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.msg))
            .collect::<Vec<_>>()
            .join("\n"),
    );
}
