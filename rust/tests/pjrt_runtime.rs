//! PJRT artifact integration: load every shipped artifact, execute, and
//! check numerics against the Rust scalar oracles. Requires
//! `make artifacts` (the Makefile runs it before tests); each test
//! no-ops with a notice when artifacts are absent so `cargo test` alone
//! stays green.

use sfc_part::runtime::artifact::ArtifactDir;
use sfc_part::runtime::exec::{
    spmv_bell_ref, Engine, KNN_C, KNN_D, KNN_K, KNN_Q, MORTON_BITS, MORTON_D, MORTON_N, SPMV_BS,
    SPMV_KMAX, SPMV_N, SPMV_NR,
};
use sfc_part::util::rng::{Rng, SplitMix64};

fn engine() -> Option<Engine> {
    match Engine::new(&ArtifactDir::default_dir()) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping PJRT test: {e}");
            None
        }
    }
}

fn random_tile(seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let mut blocks = vec![0.0f32; SPMV_NR * SPMV_KMAX * SPMV_BS * SPMV_BS];
    for v in blocks.iter_mut() {
        if rng.below(4) == 0 {
            *v = (rng.next_f64() as f32) - 0.5;
        }
    }
    let cols: Vec<i32> =
        (0..SPMV_NR * SPMV_KMAX).map(|_| rng.below((SPMV_N / SPMV_BS) as u64) as i32).collect();
    let x: Vec<f32> = (0..SPMV_N).map(|_| rng.next_f64() as f32).collect();
    (blocks, cols, x)
}

#[test]
fn spmv_artifact_matches_scalar_oracle() {
    let Some(engine) = engine() else { return };
    for seed in [1u64, 2, 3] {
        let (blocks, cols, x) = random_tile(seed);
        let got = engine.spmv_bell(&blocks, &cols, &x).unwrap();
        let want = spmv_bell_ref(&blocks, &cols, &x);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b} (seed {seed})");
        }
    }
}

#[test]
fn pagerank_step_artifact_is_stochastic() {
    let Some(engine) = engine() else { return };
    let (blocks, cols, _) = random_tile(7);
    let blocks: Vec<f32> = blocks.iter().map(|v| v.abs()).collect();
    let x = vec![1.0f32 / SPMV_N as f32; SPMV_N];
    let y = engine.pagerank_step(&blocks, &cols, &x, 0.85).unwrap();
    let sum: f32 = y.iter().sum();
    assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
    assert!(y.iter().all(|&v| v > 0.0));
}

#[test]
fn knn_artifact_matches_scalar_topk() {
    let Some(engine) = engine() else { return };
    let mut rng = SplitMix64::new(9);
    let q: Vec<f32> = (0..KNN_Q * KNN_D).map(|_| rng.next_f64() as f32).collect();
    let c: Vec<f32> = (0..KNN_C * KNN_D).map(|_| rng.next_f64() as f32).collect();
    let (dist, idx) = engine.knn_topk(&q, &c).unwrap();
    assert_eq!(dist.len(), KNN_Q * KNN_K);
    assert_eq!(idx.len(), KNN_Q * KNN_K);
    // Scalar oracle for a few queries.
    for qi in [0usize, 17, KNN_Q - 1] {
        let mut d2: Vec<(f32, usize)> = (0..KNN_C)
            .map(|ci| {
                let mut acc = 0.0f32;
                for d in 0..KNN_D {
                    let diff = q[qi * KNN_D + d] - c[ci * KNN_D + d];
                    acc += diff * diff;
                }
                (acc, ci)
            })
            .collect();
        d2.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for k in 0..KNN_K {
            let got = dist[qi * KNN_K + k];
            let want = d2[k].0;
            assert!((got - want).abs() <= 1e-4 * want.max(1.0), "q{qi} k{k}: {got} vs {want}");
        }
        // Indices must point at candidates with matching distances.
        for k in 0..KNN_K {
            let ci = idx[qi * KNN_K + k] as usize;
            let mut acc = 0.0f32;
            for d in 0..KNN_D {
                let diff = q[qi * KNN_D + d] - c[ci * KNN_D + d];
                acc += diff * diff;
            }
            assert!((acc - dist[qi * KNN_K + k]).abs() <= 1e-4 * acc.max(1.0));
        }
    }
}

#[test]
fn morton_artifact_matches_rust_bits() {
    let Some(engine) = engine() else { return };
    let mut rng = SplitMix64::new(11);
    let coords: Vec<f32> = (0..MORTON_N * MORTON_D).map(|_| rng.next_f64() as f32).collect();
    let keys = engine.morton_keys(&coords).unwrap();
    assert_eq!(keys.len(), MORTON_N);
    // Rust oracle: the quantized kernel key truncated to D*bits bits,
    // compared as the top 30 bits of the u128 path key.
    for i in (0..MORTON_N).step_by(37) {
        let p = [
            coords[i * MORTON_D] as f64,
            coords[i * MORTON_D + 1] as f64,
            coords[i * MORTON_D + 2] as f64,
        ];
        let full = sfc_part::sfc::kernel::morton_key_quantized(
            &p,
            &sfc_part::geom::bbox::BoundingBox::unit(MORTON_D),
            (MORTON_D as u32 * MORTON_BITS) as u16,
        );
        let top = (full >> (128 - (MORTON_D as u32 * MORTON_BITS))) as u32;
        assert_eq!(keys[i], top, "point {i}: {:?}", p);
    }
}

#[test]
fn tiled_pjrt_spmv_matches_csr() {
    let Some(engine) = engine() else { return };
    let g = sfc_part::graph::rmat::rmat(
        sfc_part::graph::rmat::RmatParams::graph500(9, 6.0),
        13,
    );
    let report = sfc_part::runtime::spmv_driver::run_pjrt_spmv(&engine, &g, 3).unwrap();
    eprintln!("{report}");
    // The report embeds the max relative error; parse and bound it.
    let err: f64 = report
        .split("rel_err=")
        .nth(1)
        .unwrap()
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .unwrap();
    assert!(err < 1e-4, "relative error {err}");
}
