//! Property-based invariant suites over the whole stack, using the
//! in-crate `util::prop` framework (proptest is unavailable offline).

use sfc_part::geom::bbox::BoundingBox;
use sfc_part::geom::point::PointSet;
use sfc_part::kdtree::builder::KdTreeBuilder;
use sfc_part::kdtree::dynamic::DynKdTree;
use sfc_part::kdtree::splitter::{DimRule, SplitterConfig, SplitterKind};
use sfc_part::partition::incremental::rebalance;
use sfc_part::partition::knapsack::{greedy_knapsack, max_load_diff, part_loads};
use sfc_part::partition::partitioner::{PartitionConfig, Partitioner};
use sfc_part::query::point_location::BucketIndex;
use sfc_part::sfc::traverse::{assign_sfc, keys_strictly_increasing};
use sfc_part::sfc::Curve;
use sfc_part::util::prop::{forall, Gen};

fn random_points(g: &mut Gen, max_n: usize) -> PointSet {
    let n = g.usize_in(2, max_n);
    let dim = g.usize_in(2, 5);
    let mut ps = PointSet::new(dim);
    ps.coords = g.coords(n, dim);
    ps.ids = (0..n as u64).collect();
    ps.weights = g.weights(n, 8.0);
    ps
}

#[test]
fn prop_tree_invariants_any_splitter() {
    forall("tree-invariants", 40, |g| {
        let ps = random_points(g, 400);
        let kind = match g.usize_in(0, 4) {
            0 => SplitterKind::Midpoint,
            1 => SplitterKind::MedianSort,
            2 => SplitterKind::MedianSample { sample: 64 },
            _ => SplitterKind::MedianSelect { sample: 64 },
        };
        let bucket = g.usize_in(1, 40);
        let tree = KdTreeBuilder::new()
            .bucket_size(bucket)
            .splitter(SplitterConfig::uniform(kind))
            .threads(g.usize_in(1, 4))
            .build(&ps);
        match tree.check_invariants(&ps.coords, &ps.weights) {
            Ok(()) => (true, String::new()),
            Err(e) => (false, format!("{kind:?} bucket={bucket} n={}: {e}", ps.len())),
        }
    });
}

#[test]
fn prop_sfc_keys_strict_and_perm_valid() {
    forall("sfc-keys-strict", 30, |g| {
        let ps = random_points(g, 300);
        let curve = if g.bool() { Curve::Morton } else { Curve::HilbertLike };
        let mut tree = KdTreeBuilder::new().bucket_size(g.usize_in(1, 16)).build(&ps);
        assign_sfc(&mut tree, curve);
        let strict = keys_strictly_increasing(&tree);
        let mut perm = tree.perm.clone();
        perm.sort_unstable();
        let valid = perm == (0..ps.len() as u32).collect::<Vec<u32>>();
        (strict && valid, format!("curve={curve} n={} strict={strict} valid={valid}", ps.len()))
    });
}

#[test]
fn prop_knapsack_bound_holds_everywhere() {
    forall("knapsack-bound", 150, |g| {
        let n = g.usize_in(1, 500);
        let parts = g.usize_in(1, 24);
        let w = g.weights(n, 30.0);
        let assign = greedy_knapsack(&w, parts);
        let loads = part_loads(&assign, &w, parts);
        let wmax = w.iter().copied().fold(0.0f32, f32::max) as f64;
        let total: f64 = w.iter().map(|&x| x as f64).sum();
        let target = total / parts as f64;
        let mx = loads.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        (
            mx <= target + wmax + 1e-9,
            format!("n={n} p={parts} max={mx} target={target} wmax={wmax}"),
        )
    });
}

#[test]
fn prop_partition_balanced_and_contiguous() {
    forall("partition-balance", 25, |g| {
        let ps = random_points(g, 400);
        let parts = g.usize_in(2, 9);
        let cfg = PartitionConfig {
            parts,
            bucket_size: g.usize_in(2, 32),
            curve: if g.bool() { Curve::Morton } else { Curve::HilbertLike },
            ..Default::default()
        };
        let plan = Partitioner::new(cfg).partition(&ps);
        let wmax = ps.weights.iter().copied().fold(0.0f32, f32::max) as f64;
        let balanced = plan.max_load_diff() <= wmax + ps.total_weight() / parts as f64 + 1e-9;
        let on_curve: Vec<u32> = plan.perm.iter().map(|&pi| plan.part_of[pi as usize]).collect();
        let contiguous = on_curve.windows(2).all(|w| w[0] <= w[1]);
        (
            balanced && contiguous,
            format!("n={} p={parts} diff={} wmax={wmax}", ps.len(), plan.max_load_diff()),
        )
    });
}

#[test]
fn prop_parallel_matches_serial() {
    // The tentpole determinism guarantee: for any thread count the full
    // Algorithm 2 pipeline produces bit-identical perm / part_of / loads.
    forall("parallel-matches-serial", 12, |g| {
        let ps = random_points(g, 500);
        let parts = g.usize_in(2, 9);
        let bucket = g.usize_in(2, 32);
        let curve = if g.bool() { Curve::Morton } else { Curve::HilbertLike };
        let kind = match g.usize_in(0, 3) {
            0 => SplitterKind::Midpoint,
            1 => SplitterKind::MedianSort,
            _ => SplitterKind::MedianSelect { sample: 128 },
        };
        let run = |threads: usize| {
            let cfg = PartitionConfig {
                parts,
                bucket_size: bucket,
                curve,
                splitter: SplitterConfig::uniform(kind),
                threads,
                ..Default::default()
            };
            Partitioner::new(cfg).partition(&ps)
        };
        let base = run(1);
        for threads in [2usize, 4, 8] {
            let plan = run(threads);
            if plan.perm != base.perm
                || plan.part_of != base.part_of
                || plan.loads != base.loads
            {
                return (
                    false,
                    format!(
                        "threads={threads} diverged (n={} parts={parts} {kind:?} {curve})",
                        ps.len()
                    ),
                );
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_incremental_never_worse_than_stale() {
    forall("incremental-improves", 60, |g| {
        let n = g.usize_in(10, 400);
        let parts = g.usize_in(2, 8);
        let w0 = g.weights(n, 5.0);
        let p0 = greedy_knapsack(&w0, parts);
        // Perturb weights.
        let mut w1 = w0.clone();
        let lo = g.usize_in(0, n - 1);
        let hi = g.usize_in(lo + 1, n + 1).min(n);
        for item in w1.iter_mut().take(hi).skip(lo) {
            *item *= 1.0 + g.f64_in(0.0, 2.0) as f32;
        }
        let rb = rebalance(&p0, &w1, parts);
        let stale = max_load_diff(&part_loads(&p0, &w1, parts));
        let fresh = max_load_diff(&part_loads(&rb.part_in_order, &w1, parts));
        (fresh <= stale + 1e-6, format!("n={n} p={parts} stale={stale} fresh={fresh}"))
    });
}

#[test]
fn prop_point_location_total_on_stored_points() {
    forall("point-location-total", 20, |g| {
        let n = g.usize_in(10, 300);
        let dim = g.usize_in(2, 4);
        let mut ps = PointSet::new(dim);
        ps.coords = g.coords(n, dim);
        ps.ids = (0..n as u64).collect();
        ps.weights = vec![1.0; n];
        let mut cfg = SplitterConfig::uniform(SplitterKind::Midpoint);
        cfg.dim_rule = DimRule::Cycle;
        let mut tree = KdTreeBuilder::new()
            .bucket_size(g.usize_in(1, 16))
            .splitter(cfg)
            .domain(BoundingBox::unit(dim))
            .build(&ps);
        assign_sfc(&mut tree, Curve::Morton);
        let idx = BucketIndex::from_tree(&tree, BoundingBox::unit(dim));
        for i in 0..n {
            // Duplicate coords may legitimately return a different id at
            // distance 0; accept any exact-distance hit.
            match idx.locate_point(&ps, ps.point(i), 1e-12) {
                Some(j) => {
                    if ps.dist2(i, j as usize) > 1e-20 {
                        return (false, format!("i={i} got far j={j}"));
                    }
                }
                None => return (false, format!("i={i} not found (n={n} dim={dim})")),
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_dynamic_tree_conserves_points() {
    forall("dynamic-conservation", 20, |g| {
        let ps = random_points(g, 200);
        let bucket = g.usize_in(2, 24);
        let mut t = DynKdTree::from_points(&ps, bucket, 5);
        let mut expected = ps.len();
        // Random insert/delete churn.
        for step in 0..g.usize_in(1, 30) {
            if g.bool() {
                let mut c = vec![0.0; ps.dim];
                for v in c.iter_mut() {
                    *v = g.f64_in(0.0, 1.0);
                }
                t.insert(&c, 10_000 + step as u64, 1.0);
                expected += 1;
            } else {
                let victim = g.usize_in(0, ps.len());
                let coords: Vec<f64> = ps.point(victim).to_vec();
                if t.delete(&coords, victim as u64) {
                    expected -= 1;
                }
            }
        }
        t.adjustments();
        if let Err(e) = t.check_invariants() {
            return (false, e);
        }
        (t.n_points() == expected, format!("n={} expected={expected}", t.n_points()))
    });
}

/// Duplicate-heavy point set: a handful of repeated sites plus a
/// sprinkle of unique points. Exercises degenerate (zero-width) top
/// leaves in the distributed build.
fn duplicate_heavy_points(g: &mut Gen, max_n: usize) -> PointSet {
    let n = g.usize_in(32, max_n);
    let dim = g.usize_in(2, 4);
    let sites = g.usize_in(2, 6);
    let site_coords = g.coords(sites, dim);
    let mut ps = PointSet::new(dim);
    for i in 0..n {
        let unique = g.u64_below(4) == 0;
        let c: Vec<f64> = if unique {
            (0..dim).map(|_| g.f64_in(0.0, 1.0)).collect()
        } else {
            let s = g.usize_in(0, sites);
            site_coords[s * dim..(s + 1) * dim].to_vec()
        };
        ps.push(&c, i as u64, 1.0);
    }
    ps
}

fn shard(ps: &PointSet, rank: usize, p: usize) -> PointSet {
    ps.mod_shard(rank, p)
}

/// Rank counts to sweep: `SFC_TEST_RANKS=2` (or a comma list) narrows
/// the sweep — CI uses it to run the distributed suite at 2 and 8
/// simulated ranks.
fn rank_sweep() -> Vec<usize> {
    match std::env::var("SFC_TEST_RANKS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("SFC_TEST_RANKS wants integers"))
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    }
}

#[test]
fn prop_distributed_global_sfc_order_invariant() {
    use sfc_part::partition::distributed::distributed_partition;
    use sfc_part::runtime_sim::{run_ranks, CostModel};
    // §III-C invariant across rank counts, splitters, and duplicate-heavy
    // inputs: shards conserve the input, per-rank keys are sorted, and
    // all keys on rank i precede all keys on rank j > i.
    forall("distributed-global-order", 5, |g| {
        let ps = duplicate_heavy_points(g, 400);
        let n = ps.len();
        for kind in [SplitterKind::Midpoint, SplitterKind::MedianSort] {
            for &p in &rank_sweep() {
                let (outs, _) = run_ranks(p, CostModel::default(), |ctx| {
                    let local = shard(&ps, ctx.rank, p);
                    let cfg = PartitionConfig {
                        splitter: SplitterConfig::uniform(kind),
                        ..Default::default()
                    };
                    let dp = distributed_partition(ctx, &local, &cfg, 4 * p);
                    (dp.local.ids.clone(), dp.keys.clone())
                });
                let mut all: Vec<u64> =
                    outs.iter().flat_map(|(ids, _)| ids.iter().copied()).collect();
                all.sort_unstable();
                if all != (0..n as u64).collect::<Vec<u64>>() {
                    return (false, format!("p={p} {kind:?} n={n}: ids not conserved"));
                }
                // Per-rank keys sorted, and strictly increasing across
                // ranks — tracked through empty ranks, so a violation
                // across a rank that received no points is still caught.
                let mut prev: Option<(usize, u128)> = None;
                for (r, (_, keys)) in outs.iter().enumerate() {
                    if keys.windows(2).any(|w| w[0] > w[1]) {
                        return (false, format!("p={p} {kind:?} rank {r}: keys unsorted"));
                    }
                    let (Some(&first), Some(&last)) = (keys.first(), keys.last()) else {
                        continue;
                    };
                    if let Some((pr, pmax)) = prev {
                        if pmax >= first {
                            return (
                                false,
                                format!("p={p} {kind:?}: rank {pr} max key !< rank {r} min"),
                            );
                        }
                    }
                    prev = Some((r, last));
                }
            }
        }
        (true, String::new())
    });
}

/// Shared body of the thread-invariance checks: distributed outputs
/// must be bit-identical for threads-per-rank ∈ {1, 2, 4} at fixed `p`.
fn distributed_is_thread_invariant(ps: &PointSet, p: usize, kind: SplitterKind) -> bool {
    use sfc_part::partition::distributed::distributed_partition;
    use sfc_part::runtime_sim::{run_ranks_threaded, CostModel};
    let run = |tpr: usize| {
        run_ranks_threaded(p, tpr, CostModel::default(), |ctx| {
            let local = shard(ps, ctx.rank, p);
            let cfg =
                PartitionConfig { splitter: SplitterConfig::uniform(kind), ..Default::default() };
            let dp = distributed_partition(ctx, &local, &cfg, 4 * p);
            (dp.local.ids.clone(), dp.keys.clone(), dp.owned_leaves)
        })
        .0
    };
    let base = run(1);
    [2usize, 4].iter().all(|&tpr| run(tpr) == base)
}

#[test]
fn prop_distributed_outputs_thread_invariant() {
    // Acceptance invariant: `DistPartition` (keys, migrated shard,
    // owned leaves) is bit-identical for any threads-per-rank value at a
    // fixed rank count.
    forall("distributed-thread-invariance", 3, |g| {
        let ps = duplicate_heavy_points(g, 300);
        for kind in [SplitterKind::Midpoint, SplitterKind::MedianSort] {
            for &p in &rank_sweep() {
                if !distributed_is_thread_invariant(&ps, p, kind) {
                    return (false, format!("p={p} {kind:?}: output diverged across threads"));
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn distributed_thread_invariant_across_block_boundary() {
    // The small property cases above stay below TOP_BLOCK (4096 points
    // per leaf list), exercising only the serial fallback of the blocked
    // passes. This fixed case puts 18k duplicate-heavy points on 2
    // ranks (root lists = 9k, several blocks), so the multi-block merge
    // order itself is what's being pinned.
    let uni = PointSet::uniform(18_000, 3, 99);
    let mut ps = PointSet::new(3);
    for i in 0..uni.len() {
        if i % 3 == 0 {
            ps.push(uni.point(i), i as u64, 1.0);
        } else {
            // Two thirds of the points pile onto four fixed sites.
            let s = (i % 4) as f64;
            ps.push(&[0.1 + 0.2 * s, 0.3, 0.7], i as u64, 1.0);
        }
    }
    for kind in [SplitterKind::Midpoint, SplitterKind::MedianSort] {
        assert!(
            distributed_is_thread_invariant(&ps, 2, kind),
            "{kind:?}: output diverged across threads at multi-block scale"
        );
    }
}

#[test]
fn prop_partition_thread_invariant_on_duplicates() {
    // The shared-memory pipeline's determinism guarantee must also hold
    // on duplicate-heavy inputs (degenerate splits everywhere).
    forall("partition-duplicates-thread-invariance", 8, |g| {
        let ps = duplicate_heavy_points(g, 400);
        let parts = g.usize_in(2, 9);
        let run = |threads: usize| {
            let cfg = PartitionConfig { parts, threads, ..Default::default() };
            Partitioner::new(cfg).partition(&ps)
        };
        let base = run(1);
        for threads in [2usize, 4] {
            let plan = run(threads);
            if plan.perm != base.perm || plan.part_of != base.part_of || plan.loads != base.loads {
                return (false, format!("threads={threads} parts={parts} diverged"));
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_distributed_median_multiprobe_matches_bisection() {
    use sfc_part::partition::distributed::{distributed_median, distributed_median_bisect};
    use sfc_part::runtime_sim::{run_ranks, CostModel};
    // The multi-probe median must agree with the classic 40-round
    // bisection across rank counts and input shapes. "Agree" means an
    // equivalent split: the two values bracket the same ≤-count (both
    // searches may exit early anywhere inside a wide value gap whose
    // every point is an exact median), or — when the counts differ, i.e.
    // the returned values straddle a count jump — the values themselves
    // coincide within the bracket epsilon.
    forall("distributed-median-multiprobe", 4, |g| {
        for mode in 0..3u32 {
            let ps = match mode {
                // uniform
                0 => {
                    let n = g.usize_in(64, 400);
                    let dim = g.usize_in(2, 4);
                    let mut ps = PointSet::new(dim);
                    ps.coords = g.coords(n, dim);
                    ps.ids = (0..n as u64).collect();
                    ps.weights = vec![1.0; n];
                    ps
                }
                // clustered
                1 => PointSet::clustered(g.usize_in(64, 400), 3, 0.6, g.u64_below(1000)),
                // duplicate-heavy
                _ => duplicate_heavy_points(g, 400),
            };
            let bbox = ps.bounding_box();
            let d = bbox.widest_dim();
            if bbox.width(d) <= 0.0 {
                continue;
            }
            let n = ps.len() as u64;
            let scale = bbox.width(d).max(1.0);
            for &p in &rank_sweep() {
                let (outs, _) = run_ranks(p, CostModel::default(), |ctx| {
                    let local = shard(&ps, ctx.rank, p);
                    let list: Vec<u32> = (0..local.len() as u32).collect();
                    let multi =
                        distributed_median(ctx, &local, &list, d, &bbox, n, ctx.threads);
                    let bisect =
                        distributed_median_bisect(ctx, &local, &list, d, &bbox, n, ctx.threads);
                    (multi, bisect)
                });
                // Every rank resolves the same values.
                if outs.iter().any(|o| *o != outs[0]) {
                    return (false, format!("p={p} mode={mode}: ranks disagree"));
                }
                let ((multi, rounds), bisect) = outs[0];
                if rounds > 13 {
                    return (false, format!("p={p} mode={mode}: {rounds} rounds > 13"));
                }
                let cnt = |v: f64| (0..ps.len()).filter(|&i| ps.coord(i, d) <= v).count();
                let (cm, cb) = (cnt(multi), cnt(bisect));
                let equivalent_split = cm == cb;
                let same_value = (multi - bisect).abs() <= 1e-8 * scale;
                if !(equivalent_split || same_value) {
                    return (
                        false,
                        format!(
                            "p={p} mode={mode} n={n}: multi={multi} (cnt {cm}) vs \
                             bisect={bisect} (cnt {cb})"
                        ),
                    );
                }
                // The observed-value guarantee: the multi-probe split is
                // never one-sided (the bisection's duplicate-lane bug).
                if cm == 0 || cm == ps.len() {
                    return (false, format!("p={p} mode={mode}: one-sided multi-probe split"));
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_migrate_pack_parallel_is_byte_identical() {
    use sfc_part::migrate::{pack, pack_parallel};
    // The parallel pack preserves the wire format byte-for-byte for any
    // thread count, destination mix, and shard size (crossing the block
    // boundary so the multi-block path is exercised).
    forall("pack-parallel-identical", 8, |g| {
        let n = g.usize_in(2, 20_000);
        let dim = g.usize_in(2, 4);
        let mut ps = PointSet::new(dim);
        ps.coords = g.coords(n, dim);
        ps.ids = (0..n as u64).collect();
        ps.weights = g.weights(n, 8.0);
        let p = g.usize_in(1, 9);
        let dest: Vec<u32> = (0..n).map(|_| g.u64_below(p as u64) as u32).collect();
        let serial = pack(&ps, &dest, p);
        for t in [1usize, 2, 4, 8] {
            if pack_parallel(&ps, &dest, p, t) != serial {
                return (false, format!("n={n} p={p} threads={t}: bytes diverged"));
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_transfer_t_l_t_matches_serial_wire_path() {
    use sfc_part::migrate::{pack, transfer_t_l_t, unpack};
    use sfc_part::runtime_sim::{run_ranks_threaded, CostModel};
    // The parallel receive path (pack_parallel → rounds → unpack_parallel)
    // must be bit-identical to the serial pack/unpack reference for every
    // threads-per-rank × max_msg × duplicate-heavy input. The reference
    // is computed outside the fabric: pack each rank's shard serially,
    // route buffer [src][dst], and serially unpack per destination in
    // source order — exactly what `transfer_t_l_t` did before it went
    // parallel.
    forall("transfer-matches-serial-path", 4, |g| {
        let ps = duplicate_heavy_points(g, 600);
        let dim = ps.dim;
        let p = g.usize_in(2, 5);
        let max_msg = [64usize, 4096, 1 << 20][g.usize_in(0, 3)];
        // Destination: by id hash, so ranks exchange uneven buffers.
        let dest_of = |ids: &[u64]| -> Vec<u32> {
            ids.iter().map(|&id| ((id.wrapping_mul(0x9e3779b9)) % p as u64) as u32).collect()
        };
        // Serial reference: per-destination buffers in source order.
        let mut expected: Vec<sfc_part::geom::point::PointSet> =
            (0..p).map(|_| sfc_part::geom::point::PointSet::new(dim)).collect();
        {
            let routed: Vec<Vec<Vec<u8>>> = (0..p)
                .map(|src| {
                    let local = shard(&ps, src, p);
                    pack(&local, &dest_of(&local.ids), p)
                })
                .collect();
            for (dst, exp) in expected.iter_mut().enumerate() {
                for routed_src in routed.iter() {
                    unpack(&routed_src[dst], dim, exp);
                }
            }
        }
        for tpr in [1usize, 2, 4] {
            let (outs, _) = run_ranks_threaded(p, tpr, CostModel::default(), |ctx| {
                let local = shard(&ps, ctx.rank, p);
                let dest = dest_of(&local.ids);
                transfer_t_l_t(ctx, &local, &dest, max_msg)
            });
            for (r, (got, want)) in outs.iter().zip(&expected).enumerate() {
                if got.ids != want.ids || got.weights != want.weights || got.coords != want.coords
                {
                    return (
                        false,
                        format!("p={p} tpr={tpr} max_msg={max_msg} rank {r}: shard diverged"),
                    );
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_merge_runs_match_cursor_scan_reference() {
    use sfc_part::util::sort::{
        merge_runs_cursor_scan, merge_runs_loser_tree, parallel_merge_runs,
    };
    // The loser tree and the pool-backed pairwise merge must both equal
    // the old cursor-scan merge (kept as the reference) on sorted runs
    // with heavy duplication, including empty runs, for every thread
    // count.
    forall("merge-runs-reference", 25, |g| {
        let k = g.usize_in(1, 12);
        let runs: Vec<Vec<f64>> = (0..k)
            .map(|_| {
                let len = g.usize_in(0, 300);
                // Small key space → many cross-run duplicates.
                let mut r: Vec<f64> = (0..len).map(|_| g.u64_below(9) as f64 * 0.125).collect();
                r.sort_by(|a, b| a.partial_cmp(b).unwrap());
                r
            })
            .collect();
        let want = merge_runs_cursor_scan(&runs, |v| *v);
        if merge_runs_loser_tree(&runs, |v| *v) != want {
            return (false, format!("k={k}: loser tree diverged from cursor scan"));
        }
        for t in [1usize, 2, 4, 8] {
            if parallel_merge_runs(t, runs.clone(), |v| *v) != want {
                return (false, format!("k={k} t={t}: parallel merge diverged"));
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_sample_sort_balances_duplicate_heavy_lanes() {
    use sfc_part::runtime_sim::sample_sort::sample_sort_f64;
    use sfc_part::runtime_sim::{run_ranks, CostModel};
    // Regression property for the tie-skew bug: when ~80% of keys equal
    // one value, the old `v <= sp` bucket walk collapsed the whole
    // duplicate mass onto a single shard (≥ 80% of the data on one
    // rank). With tie splitting the worst case is p = 2 with an
    // off-center site: half the tie mass (~40%) plus one uniform tail
    // (≤ 18%) — comfortably under the 70% bound asserted here.
    forall("sample-sort-duplicate-balance", 6, |g| {
        let p = g.usize_in(2, 6);
        let n_per = g.usize_in(200, 500);
        let site = g.f64_in(0.1, 0.9);
        let seed = g.u64_below(1 << 40);
        let (outs, _) = run_ranks(p, CostModel::default(), move |ctx| {
            use sfc_part::util::rng::{Rng, SplitMix64};
            let mut rng = SplitMix64::new(seed ^ ctx.rank as u64);
            let local: Vec<f64> = (0..n_per)
                .map(|_| if rng.below(5) < 4 { site } else { rng.uniform(0.0, 1.0) })
                .collect();
            sample_sort_f64(ctx, local, 16)
        });
        let total: usize = outs.iter().map(|o| o.len()).sum();
        if total != p * n_per {
            return (false, format!("p={p}: content lost ({total} of {})", p * n_per));
        }
        for i in 0..p - 1 {
            if let (Some(a), Some(b)) = (outs[i].last(), outs[i + 1].first()) {
                if a > b {
                    return (false, format!("p={p}: order violated across ranks {i},{}", i + 1));
                }
            }
        }
        let max = outs.iter().map(|o| o.len()).max().unwrap();
        (
            max <= total * 7 / 10,
            format!("p={p} n_per={n_per}: max shard {max} of {total} (duplicate collapse)"),
        )
    });
}

#[test]
fn prop_collectives_agree_with_local_reduction() {
    use sfc_part::runtime_sim::collectives::ReduceOp;
    use sfc_part::runtime_sim::{run_ranks, CostModel};
    forall("collectives-sum", 15, |g| {
        let p = g.usize_in(1, 9);
        let vals: Vec<f64> = (0..p).map(|_| g.f64_in(-10.0, 10.0)).collect();
        let expect: f64 = vals.iter().sum();
        let vals2 = vals.clone();
        let (outs, _) = run_ranks(p, CostModel::default(), move |ctx| {
            ctx.allreduce1(ReduceOp::Sum, vals2[ctx.rank])
        });
        let ok = outs.iter().all(|&v| (v - expect).abs() < 1e-9);
        (ok, format!("p={p} outs={outs:?} expect={expect}"))
    });
}
