//! Model checks of the two lock-free protocols in the unsafe core,
//! run through the bounded exhaustive interleaving explorer
//! (`sfc_part::util::sched`) — loom-style, without the dependency.
//!
//! * the multi-job thread pool's **job-slot protocol**
//!   (`runtime_sim::threadpool::Pool::run` + `worker_loop`): publish →
//!   claim/execute under a round-robin worker cap → drain-wait → clear;
//! * `kdtree::conc_list::ConcList`'s **publish/snapshot protocol**:
//!   CAS-retry block prepend with a lagging length counter and
//!   prefix-stable reader snapshots.
//!
//! Steps are modeled at mutex/CAS granularity — each step is one
//! lock-held region or one atomic — so the explorer's interleavings
//! cover every point where the real code yields exclusivity.
//!
//! Default runs use small configurations; `RUSTFLAGS="--cfg loom"`
//! (the CI loom lane) switches to larger ones.

use sfc_part::util::sched::{Explorer, Model, Status};

fn max_states() -> usize {
    if cfg!(loom) {
        5_000_000
    } else {
        500_000
    }
}

// ---------------------------------------------------------------------
// Job-slot protocol (threadpool.rs)
// ---------------------------------------------------------------------

/// Thread 0 is the caller (`Pool::run`); threads 1.. are pool workers
/// (`worker_loop`). Shared state mirrors one `JobSlot` plus the
/// per-work-item execution counts the SAFETY argument rests on.
#[derive(Clone, PartialEq, Eq, Hash)]
struct JobSlotModel {
    ids: usize,
    /// `concurrency - 1`: max workers engaged at once.
    limit: usize,
    // --- shared slot (mutations happen under the pool mutex) ---
    published: bool,
    cleared: bool,
    next: usize,
    running: usize,
    exec_count: Vec<u8>,
    // --- caller: 0 publish, 1 claim, 2 exec, 3 drain+clear, 4 done ---
    caller_pc: u8,
    caller_id: usize,
    // --- workers: 0 scan/engage, 1 claim, 2 exec, 3 exited ---
    worker_pc: Vec<u8>,
    worker_id: Vec<usize>,
}

impl JobSlotModel {
    fn new(ids: usize, workers: usize, limit: usize) -> Self {
        JobSlotModel {
            ids,
            limit,
            published: false,
            cleared: false,
            next: 0,
            running: 0,
            exec_count: vec![0; ids],
            caller_pc: 0,
            caller_id: 0,
            worker_pc: vec![0; workers],
            worker_id: vec![0; workers],
        }
    }

    /// `JobSlot::claimable` from the worker's point of view.
    fn claimable(&self) -> bool {
        self.published && !self.cleared && self.next < self.ids && self.running < self.limit
    }

    fn exec(&mut self, id: usize) {
        self.exec_count[id] += 1;
        assert_eq!(self.exec_count[id], 1, "work id {id} executed twice");
        assert!(!self.cleared, "execution after the slot was cleared");
    }
}

impl Model for JobSlotModel {
    fn threads(&self) -> usize {
        1 + self.worker_pc.len()
    }

    fn status(&self, t: usize) -> Status {
        if t == 0 {
            return match self.caller_pc {
                0 | 1 | 2 => Status::Runnable,
                // done_cv wait: runnable only once every worker left.
                3 => {
                    if self.running == 0 {
                        Status::Runnable
                    } else {
                        Status::Blocked
                    }
                }
                _ => Status::Done,
            };
        }
        let w = t - 1;
        match self.worker_pc[w] {
            // work_cv wait: wakes for a claimable slot, or exits once
            // the job is gone (parked workers take no more steps).
            0 => {
                if self.claimable() || self.cleared {
                    Status::Runnable
                } else {
                    Status::Blocked
                }
            }
            1 | 2 => Status::Runnable,
            _ => Status::Done,
        }
    }

    fn step(&mut self, t: usize) {
        if t == 0 {
            match self.caller_pc {
                // Publish the job (slot setup + work_cv notify).
                0 => {
                    self.published = true;
                    self.caller_pc = 1;
                }
                // Claim the next id under the lock, or move to drain.
                1 => {
                    if self.next < self.ids {
                        self.caller_id = self.next;
                        self.next += 1;
                        self.caller_pc = 2;
                    } else {
                        self.caller_pc = 3;
                    }
                }
                // Execute outside the lock.
                2 => {
                    let id = self.caller_id;
                    self.exec(id);
                    self.caller_pc = 1;
                }
                // running == 0 (checked by status): clear the slot.
                3 => {
                    assert_eq!(self.running, 0);
                    assert!(self.next >= self.ids, "cleared with unclaimed work");
                    assert!(
                        self.exec_count.iter().all(|&c| c == 1),
                        "cleared before every id executed"
                    );
                    self.cleared = true;
                    self.published = false;
                    self.caller_pc = 4;
                }
                _ => unreachable!(),
            }
            return;
        }
        let w = t - 1;
        match self.worker_pc[w] {
            // Scan found the slot claimable (engage), or the job is gone.
            0 => {
                if self.cleared {
                    self.worker_pc[w] = 3;
                } else {
                    assert!(self.claimable());
                    self.running += 1;
                    self.worker_pc[w] = 1;
                }
            }
            // Claim under the lock, or disengage once drained.
            1 => {
                if self.next < self.ids {
                    self.worker_id[w] = self.next;
                    self.next += 1;
                    self.worker_pc[w] = 2;
                } else {
                    self.running -= 1;
                    self.worker_pc[w] = 3;
                }
            }
            // Execute outside the lock.
            2 => {
                let id = self.worker_id[w];
                self.exec(id);
                self.worker_pc[w] = 1;
            }
            _ => unreachable!(),
        }
    }

    fn check_final(&self) {
        assert!(self.cleared, "caller never cleared the slot");
        assert_eq!(self.running, 0, "worker still engaged at exit");
        assert!(
            self.exec_count.iter().all(|&c| c == 1),
            "some work id did not execute exactly once: {:?}",
            self.exec_count
        );
    }
}

#[test]
fn job_slot_protocol_every_id_runs_exactly_once() {
    let (ids, workers, limit) = if cfg!(loom) { (4, 3, 2) } else { (3, 2, 2) };
    let stats =
        Explorer { max_states: max_states() }.explore(JobSlotModel::new(ids, workers, limit));
    assert!(!stats.truncated, "state space truncated: {stats:?}");
    assert!(stats.terminals >= 1);
}

#[test]
fn job_slot_protocol_respects_worker_limit() {
    // limit = 1: at most one worker engaged; the explorer visits every
    // schedule, so any state with running > limit would assert in
    // claimable()'s engage path.
    #[derive(Clone, PartialEq, Eq, Hash)]
    struct LimitObserved(JobSlotModel);
    impl Model for LimitObserved {
        fn threads(&self) -> usize {
            self.0.threads()
        }
        fn status(&self, t: usize) -> Status {
            self.0.status(t)
        }
        fn step(&mut self, t: usize) {
            self.0.step(t);
            assert!(self.0.running <= self.0.limit, "worker cap exceeded");
        }
        fn check_final(&self) {
            self.0.check_final();
        }
    }
    let stats = Explorer { max_states: max_states() }
        .explore(LimitObserved(JobSlotModel::new(3, 2, 1)));
    assert!(!stats.truncated, "state space truncated: {stats:?}");
}

// ---------------------------------------------------------------------
// ConcList publish/snapshot protocol (conc_list.rs)
// ---------------------------------------------------------------------

/// Block sizes pushed by each pusher thread; the last thread is a
/// reader taking a `len()` + `iter()` snapshot.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ConcListModel {
    sizes: Vec<usize>,
    /// Chain of pushed block ids, newest first (the `head` pointer walk).
    head: Vec<u8>,
    /// The lagging `len` counter (fetch_add *after* the CAS publishes).
    len: usize,
    // --- pushers: 0 load head, 1 CAS, 2 len+=, 3 done ---
    pusher_pc: Vec<u8>,
    pusher_snap: Vec<Vec<u8>>,
    // --- reader: 0 read len, 1 snapshot head, 2 re-read head, 3 done ---
    reader_pc: u8,
    reader_len: usize,
    reader_snap: Vec<u8>,
}

impl ConcListModel {
    fn new(sizes: &[usize]) -> Self {
        ConcListModel {
            sizes: sizes.to_vec(),
            head: Vec::new(),
            len: 0,
            pusher_pc: vec![0; sizes.len()],
            pusher_snap: vec![Vec::new(); sizes.len()],
            reader_pc: 0,
            reader_len: 0,
            reader_snap: Vec::new(),
        }
    }

    fn items(&self, chain: &[u8]) -> usize {
        chain.iter().map(|&b| self.sizes[b as usize]).sum()
    }
}

impl Model for ConcListModel {
    fn threads(&self) -> usize {
        self.sizes.len() + 1
    }

    fn status(&self, t: usize) -> Status {
        let pc = if t < self.sizes.len() { self.pusher_pc[t] } else { self.reader_pc };
        if pc < 3 {
            Status::Runnable
        } else {
            Status::Done
        }
    }

    fn step(&mut self, t: usize) {
        if t < self.sizes.len() {
            match self.pusher_pc[t] {
                // head.load(Acquire)
                0 => {
                    self.pusher_snap[t] = self.head.clone();
                    self.pusher_pc[t] = 1;
                }
                // compare_exchange(head, block); Err re-reads and retries
                1 => {
                    if self.head == self.pusher_snap[t] {
                        self.head.insert(0, t as u8);
                        self.pusher_pc[t] = 2;
                    } else {
                        self.pusher_snap[t] = self.head.clone();
                    }
                }
                // len.fetch_add(n) — after publication
                2 => {
                    self.len += self.sizes[t];
                    self.pusher_pc[t] = 3;
                }
                _ => unreachable!(),
            }
            return;
        }
        match self.reader_pc {
            0 => {
                self.reader_len = self.len;
                self.reader_pc = 1;
            }
            1 => {
                self.reader_snap = self.head.clone();
                // len lags publication, so a snapshot taken after the
                // len read can never show fewer items than it.
                assert!(
                    self.items(&self.reader_snap) >= self.reader_len,
                    "len counter ran ahead of published blocks"
                );
                self.reader_pc = 2;
            }
            2 => {
                // Prepend-only: an earlier snapshot stays a suffix of
                // every later head (no lost or reordered blocks).
                assert!(
                    self.head.ends_with(&self.reader_snap),
                    "snapshot is not a stable suffix of the list"
                );
                self.reader_pc = 3;
            }
            _ => unreachable!(),
        }
    }

    fn check_final(&self) {
        let mut blocks: Vec<u8> = self.head.clone();
        blocks.sort_unstable();
        let expect: Vec<u8> = (0..self.sizes.len() as u8).collect();
        assert_eq!(blocks, expect, "every pushed block exactly once");
        assert_eq!(self.len, self.sizes.iter().sum::<usize>(), "len counts every item");
    }
}

#[test]
fn conc_list_no_lost_blocks_and_exact_len() {
    let sizes: &[usize] = if cfg!(loom) { &[1, 2, 3, 4] } else { &[1, 2, 3] };
    let stats = Explorer { max_states: max_states() }.explore(ConcListModel::new(sizes));
    assert!(!stats.truncated, "state space truncated: {stats:?}");
    // Contended CAS retries mean different publication orders: with k
    // pushers every permutation of the chain must appear somewhere.
    assert!(stats.terminals > 1, "expected multiple distinct final orders: {stats:?}");
}

// ---------------------------------------------------------------------
// SpinBarrier sense-reversing protocol (threadpool.rs)
// ---------------------------------------------------------------------

/// [`sfc_part::runtime_sim::SpinBarrier::wait`] at atomic granularity,
/// crossed `rounds` times by every thread. One step per atomic op:
/// sense load → count fetch_add → (last arriver) count reset, sense
/// flip; waiters spin-block on the sense word. The reuse across rounds
/// is the interesting part — a fast thread re-arms the barrier for
/// round r+1 while round-r waiters are still between their fetch_add
/// and their sense re-read.
#[derive(Clone, PartialEq, Eq, Hash)]
struct SpinBarrierModel {
    n: usize,
    rounds: usize,
    // --- shared words ---
    count: usize,
    sense: usize,
    // --- per thread: 0 load sense, 1 fetch_add, 2 count reset,
    // 3 sense flip, 4 spin on sense ---
    pc: Vec<u8>,
    local_sense: Vec<usize>,
    /// Rounds completed per thread.
    round: Vec<usize>,
    /// Serial-thread (wait() == true) exits seen per round.
    serial: Vec<u8>,
    /// Total fetch_add arrivals across all threads and rounds.
    arrivals: usize,
}

impl SpinBarrierModel {
    fn new(n: usize, rounds: usize) -> Self {
        SpinBarrierModel {
            n,
            rounds,
            count: 0,
            sense: 0,
            pc: vec![0; n],
            local_sense: vec![0; n],
            round: vec![0; n],
            serial: vec![0; rounds],
            arrivals: 0,
        }
    }
}

impl Model for SpinBarrierModel {
    fn threads(&self) -> usize {
        self.n
    }

    fn status(&self, t: usize) -> Status {
        if self.round[t] == self.rounds {
            return Status::Done;
        }
        if self.pc[t] == 4 && self.sense == self.local_sense[t] {
            // while self.sense.load(Acquire) == sense { spin }
            Status::Blocked
        } else {
            Status::Runnable
        }
    }

    fn step(&mut self, t: usize) {
        match self.pc[t] {
            // let sense = self.sense.load(Acquire);
            0 => {
                self.local_sense[t] = self.sense;
                self.pc[t] = 1;
            }
            // self.count.fetch_add(1, AcqRel)
            1 => {
                let prev = self.count;
                self.count += 1;
                self.arrivals += 1;
                self.pc[t] = if prev == self.n - 1 { 2 } else { 4 };
            }
            // serial thread: self.count.store(0, Relaxed)
            2 => {
                self.count = 0;
                self.pc[t] = 3;
            }
            // serial thread: self.sense.store(sense + 1, Release)
            3 => {
                let r = self.round[t];
                // Barrier separation: the sense can only flip once every
                // participant of this round has arrived — and none of
                // them can have arrived for the next round yet.
                assert_eq!(
                    self.arrivals,
                    self.n * (r + 1),
                    "sense flipped for round {r} before all arrivals"
                );
                self.serial[r] += 1;
                assert_eq!(self.serial[r], 1, "two serial threads in round {r}");
                self.sense = self.local_sense[t] + 1;
                self.round[t] = r + 1;
                self.pc[t] = 0;
            }
            // spin exit (status() already saw the flipped sense)
            4 => {
                assert_eq!(
                    self.sense,
                    self.local_sense[t] + 1,
                    "waiter missed an epoch: barrier reused before it woke"
                );
                self.round[t] += 1;
                self.pc[t] = 0;
            }
            _ => unreachable!(),
        }
    }

    fn check_final(&self) {
        assert!(self.round.iter().all(|&r| r == self.rounds), "a thread skipped a round");
        assert!(self.serial.iter().all(|&s| s == 1), "rounds without exactly one serial thread");
        assert_eq!(self.count, 0, "count not re-armed at exit");
        assert_eq!(self.sense, self.rounds, "sense advanced once per round");
        assert_eq!(self.arrivals, self.n * self.rounds);
    }
}

#[test]
fn spin_barrier_exactly_one_serial_thread_per_round() {
    let (n, rounds) = if cfg!(loom) { (4, 3) } else { (3, 2) };
    let stats = Explorer { max_states: max_states() }.explore(SpinBarrierModel::new(n, rounds));
    assert!(!stats.truncated, "state space truncated: {stats:?}");
    assert!(stats.terminals >= 1);
}

#[test]
fn spin_barrier_separates_rounds_under_reuse() {
    // Two rounds with two threads is the smallest config where a fast
    // thread can re-arm the barrier while the other is still spinning —
    // the assertions inside step() check every such schedule.
    let stats = Explorer { max_states: max_states() }.explore(SpinBarrierModel::new(2, 3));
    assert!(!stats.truncated, "state space truncated: {stats:?}");
    assert!(stats.terminals >= 1);
}
