//! Property suite for the incremental repartitioning session:
//! `DistSession::repartition` swept over rank counts × load scenarios.
//!
//! Invariants per step:
//! * **conservation** — the global id multiset equals the independently
//!   evolved reference (scenario rules are pure per-point, so a global
//!   replica evolves to the same multiset);
//! * **global SFC order** — per-rank keys sorted, all keys on rank `i`
//!   strictly below all keys on rank `j > i`;
//! * **imbalance** — after the final step, no worse than a from-scratch
//!   `distributed_partition` of the same evolved points plus a
//!   tolerance (leaf granularity differs between the two, hence the
//!   slack);
//! * **determinism** — the whole multi-step run is bit-identical for
//!   every threads-per-rank at a fixed rank count.
//!
//! `SFC_TEST_RANKS` narrows the rank sweep; CI partitions it exactly as
//! it does for the `properties` suite.

use sfc_part::geom::point::PointSet;
use sfc_part::kdtree::splitter::{SplitterConfig, SplitterKind};
use sfc_part::partition::distributed::{
    distributed_partition, rebuild_step, step_ranks, DistSession, SessionConfig,
};
use sfc_part::partition::partitioner::PartitionConfig;
use sfc_part::partition::scenario::{Scenario, ScenarioKind};
use sfc_part::runtime_sim::{run_ranks_threaded, CostModel};
use sfc_part::util::prop::forall;

/// Rank counts to sweep (`SFC_TEST_RANKS=2` or a comma list narrows it;
/// CI partitions {1,4} / {2} / {8}).
fn rank_sweep() -> Vec<usize> {
    match std::env::var("SFC_TEST_RANKS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("SFC_TEST_RANKS wants integers"))
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    }
}

/// Per-rank snapshot after one step: (ids, keys, weight load).
type Snap = (Vec<u64>, Vec<u128>, f64);

/// Run create + `steps` repartitions; returns per-step per-rank snaps.
fn run_session(
    global: &PointSet,
    p: usize,
    tpr: usize,
    steps: usize,
    scenario: &Scenario,
    cfg: &PartitionConfig,
) -> Vec<Vec<Snap>> {
    let (created, _) = run_ranks_threaded(p, tpr, CostModel::default(), |ctx| {
        let local = global.mod_shard(ctx.rank, ctx.n_ranks);
        DistSession::create(ctx, &local, cfg, 4 * p, SessionConfig::default())
    });
    let mut sessions = created;
    let mut out: Vec<Vec<Snap>> = Vec::with_capacity(steps);
    for step in 0..steps {
        let (next, snaps, _) =
            step_ranks(p, tpr, CostModel::default(), sessions, |ctx, mut sess| {
                let batch = scenario.update_for(sess.local(), step);
                sess.repartition(ctx, &batch);
                let load: f64 = sess.local().weights.iter().map(|&w| w as f64).sum();
                let snap: Snap = (sess.local().ids.clone(), sess.keys().to_vec(), load);
                (sess, snap)
            });
        sessions = next;
        out.push(snaps);
    }
    out
}

/// Evolve a global replica through the scenario; returns the replica
/// after every step (the conservation + baseline reference).
fn evolve_replica(global: &PointSet, steps: usize, scenario: &Scenario) -> Vec<PointSet> {
    let mut ps = global.clone();
    let mut out = Vec::with_capacity(steps);
    for step in 0..steps {
        let batch = scenario.update_for(&ps, step);
        batch.apply_to(&mut ps);
        out.push(ps.clone());
    }
    out
}

fn sorted_ids(ps: &PointSet) -> Vec<u64> {
    let mut ids = ps.ids.clone();
    ids.sort_unstable();
    ids
}

/// Weight imbalance (max/mean − 1) of per-rank loads.
fn imbalance(loads: &[f64]) -> f64 {
    sfc_part::partition::quality::load_summary(loads).imbalance
}

/// Fresh from-scratch imbalance on an evolved global set.
fn fresh_imbalance(evolved: &PointSet, p: usize, cfg: &PartitionConfig) -> f64 {
    let (loads, _) = run_ranks_threaded(p, 1, CostModel::default(), |ctx| {
        let local = evolved.mod_shard(ctx.rank, ctx.n_ranks);
        let dp = distributed_partition(ctx, &local, cfg, 4 * p);
        dp.local.weights.iter().map(|&w| w as f64).sum::<f64>()
    });
    imbalance(&loads)
}

#[test]
fn prop_session_scenarios_preserve_invariants() {
    forall("session-scenarios", 2, |g| {
        let n = g.usize_in(600, 1100);
        let seed = g.u64_below(1000) as u32;
        let ps = PointSet::uniform(n, 3, seed);
        let steps = 2;
        let cfg = PartitionConfig::default();
        for kind in [ScenarioKind::Hotspot, ScenarioKind::Wave, ScenarioKind::Churn] {
            let scenario = Scenario::new(kind);
            let replicas = evolve_replica(&ps, steps, &scenario);
            for &p in &rank_sweep() {
                let runs = run_session(&ps, p, 1, steps, &scenario, &cfg);
                for (step, ranks_out) in runs.iter().enumerate() {
                    // Conservation against the evolved replica.
                    let mut all: Vec<u64> =
                        ranks_out.iter().flat_map(|(ids, _, _)| ids.clone()).collect();
                    all.sort_unstable();
                    if all != sorted_ids(&replicas[step]) {
                        return (
                            false,
                            format!("{kind:?} p={p} step={step}: ids not conserved"),
                        );
                    }
                    // Per-rank keys sorted; cross-rank strictly increasing
                    // (tracked through empty ranks).
                    let mut prev: Option<u128> = None;
                    for (r, (_, keys, _)) in ranks_out.iter().enumerate() {
                        if keys.windows(2).any(|w| w[0] > w[1]) {
                            return (
                                false,
                                format!("{kind:?} p={p} step={step} rank={r}: keys unsorted"),
                            );
                        }
                        let (Some(&first), Some(&last)) = (keys.first(), keys.last()) else {
                            continue;
                        };
                        if let Some(pmax) = prev {
                            if pmax >= first {
                                return (
                                    false,
                                    format!(
                                        "{kind:?} p={p} step={step}: global order broken at rank {r}"
                                    ),
                                );
                            }
                        }
                        prev = Some(last);
                    }
                }
                // Final imbalance: no worse than from-scratch + slack (the
                // two differ in leaf granularity, hence the tolerance).
                let final_loads: Vec<f64> =
                    runs[steps - 1].iter().map(|(_, _, l)| *l).collect();
                let sess_imb = imbalance(&final_loads);
                let fresh_imb = fresh_imbalance(&replicas[steps - 1], p, &cfg);
                // Theoretical sticky bound: target·(1+tol) + wmax_leaf,
                // with wmax_leaf ≤ drift_hi·total/k1 — allow that much
                // over the fresh build before calling it a failure.
                if sess_imb > (fresh_imb + 0.5).max(0.75) {
                    return (
                        false,
                        format!(
                            "{kind:?} p={p}: session imbalance {sess_imb:.3} vs fresh {fresh_imb:.3}"
                        ),
                    );
                }
                // Determinism: bit-identical run at 2 threads per rank.
                let runs2 = run_session(&ps, p, 2, steps, &scenario, &cfg);
                if runs2 != runs {
                    return (
                        false,
                        format!("{kind:?} p={p}: outputs diverged across threads-per-rank"),
                    );
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_session_hotspot_cheaper_than_rebuild() {
    // The acceptance direction at test scale, measured the same way the
    // bench measures it: collective rounds (tag epochs) and migrated
    // points of a session step vs a from-scratch rebuild per step, on
    // the moving hotspot with median splitters.
    let p = rank_sweep().into_iter().max().unwrap_or(4);
    if p < 2 {
        return; // single rank: no collectives or migration to compare
    }
    let n = 4000;
    let steps = 3;
    let global = PointSet::uniform(n, 3, 123);
    let cfg = PartitionConfig {
        splitter: SplitterConfig::uniform(SplitterKind::MedianSort),
        ..Default::default()
    };
    let scenario = Scenario::new(ScenarioKind::Hotspot);

    // Session lane.
    let (created, _) = run_ranks_threaded(p, 1, CostModel::default(), |ctx| {
        let local = global.mod_shard(ctx.rank, ctx.n_ranks);
        DistSession::create(ctx, &local, &cfg, 4 * p, SessionConfig::default())
    });
    let mut sessions = created;
    let mut sess_rounds = 0u64;
    let mut sess_migrated = 0u64;
    let mut sess_total = 0u64;
    let mut sess_final_imb = 0.0f64;
    for step in 0..steps {
        let scen = &scenario;
        let (next, outs, _) =
            step_ranks(p, 1, CostModel::default(), sessions, |ctx, mut sess| {
                let batch = scen.update_for(sess.local(), step);
                let stats = sess.repartition(ctx, &batch);
                let load: f64 = sess.local().weights.iter().map(|&w| w as f64).sum();
                (sess, (stats, load))
            });
        sessions = next;
        sess_rounds += outs.first().map(|(s, _)| s.collective_rounds).unwrap_or(0);
        sess_migrated += outs.iter().map(|(s, _)| s.migrated_out).sum::<u64>();
        sess_total += outs.iter().map(|(s, _)| s.local_points).sum::<u64>();
        let loads: Vec<f64> = outs.iter().map(|(_, l)| *l).collect();
        sess_final_imb = imbalance(&loads);
    }

    // Rebuild lane on the same evolution.
    let mut locals: Vec<PointSet> = (0..p).map(|r| global.mod_shard(r, p)).collect();
    let mut base_rounds = 0u64;
    let mut base_migrated = 0u64;
    let mut base_final_imb = 0.0f64;
    for step in 0..steps {
        let scen = &scenario;
        let cfgb = &cfg;
        let (next, outs, _) = step_ranks(p, 1, CostModel::default(), locals, |ctx, local| {
            let batch = scen.update_for(&local, step);
            let (shard, rounds, migrated) = rebuild_step(ctx, local, &batch, cfgb, 4 * p);
            let load: f64 = shard.weights.iter().map(|&w| w as f64).sum();
            (shard, (rounds, migrated, load))
        });
        locals = next;
        base_rounds += outs.first().map(|(r, _, _)| *r).unwrap_or(0);
        base_migrated += outs.iter().map(|(_, m, _)| *m).sum::<u64>();
        let loads: Vec<f64> = outs.iter().map(|(_, _, l)| *l).collect();
        base_final_imb = imbalance(&loads);
    }

    // Acceptance direction: rounds strictly under half the rebuild cost.
    assert!(
        2 * sess_rounds < base_rounds,
        "session rounds {sess_rounds} not < 50% of rebuild {base_rounds} (p={p})"
    );
    // Migration: comparable-or-better than the rebuild (10% cumulative
    // absolute slack — the strict < 50% acceptance bar is measured by the
    // `dynamic_tree` bench at its larger scale).
    assert!(
        sess_migrated <= base_migrated + sess_total / 10,
        "session migrated {sess_migrated} vs rebuild {base_migrated} of {sess_total}"
    );
    // Balance: equal or better, up to the granularity slack.
    assert!(
        sess_final_imb <= base_final_imb + 0.5,
        "session imbalance {sess_final_imb:.3} vs rebuild {base_final_imb:.3}"
    );
}
