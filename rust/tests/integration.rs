//! Cross-module integration tests: the full pipelines the paper's
//! applications run, composed end to end (without PJRT — see
//! `pjrt_runtime.rs` for the artifact-backed paths).

use sfc_part::geom::bbox::BoundingBox;
use sfc_part::geom::mesh::{RefinementDriver, SimplexMesh};
use sfc_part::geom::point::PointSet;
use sfc_part::graph::metrics::spmv_metrics;
use sfc_part::graph::pagerank::{pagerank_seq, transition_matrix};
use sfc_part::graph::partition2d::{rowwise_partition, sfc_partition};
use sfc_part::graph::rmat::{rmat, RmatParams};
use sfc_part::graph::spmv_dist::{build_plan, owned_range, spmv_step, LocalMatrix};
use sfc_part::kdtree::builder::KdTreeBuilder;
use sfc_part::kdtree::splitter::{DimRule, SplitterConfig, SplitterKind};
use sfc_part::migrate::transfer_t_l_t;
use sfc_part::partition::distributed::distributed_partition;
use sfc_part::partition::partitioner::{PartitionConfig, Partitioner};
use sfc_part::partition::quality::{edge_cut_metrics, surface_to_volume, surface_volume_summary};
use sfc_part::query::point_location::BucketIndex;
use sfc_part::query::router::{Query, QueryRouter, QueryResult};
use sfc_part::runtime_sim::collectives::ReduceOp;
use sfc_part::runtime_sim::{run_ranks, CostModel};
use sfc_part::sfc::traverse::assign_sfc;
use sfc_part::sfc::Curve;

/// Partition → migrate (simulated ranks) → verify each rank holds a
/// contiguous curve segment and balanced load (Algorithm 2 + §III-C).
#[test]
fn partition_then_migrate_contiguous_balanced() {
    let global = PointSet::uniform_weighted(4000, 3, 4.0, 3);
    let p = 6;
    let cfg = PartitionConfig { parts: p, curve: Curve::HilbertLike, ..Default::default() };
    let plan = Partitioner::new(cfg).partition(&global);

    let (outs, rep) = run_ranks(p, CostModel::default(), |ctx| {
        // Block-distributed initial shards.
        let lo = global.len() * ctx.rank / p;
        let hi = global.len() * (ctx.rank + 1) / p;
        let idx: Vec<u32> = (lo as u32..hi as u32).collect();
        let local = global.gather(&idx);
        let dest: Vec<u32> = idx.iter().map(|&i| plan.part_of[i as usize]).collect();
        let mine = transfer_t_l_t(ctx, &local, &dest, 1 << 16);
        let w: f64 = mine.total_weight();
        (mine.ids.clone(), w)
    });
    // Conservation + expected loads.
    let mut all: Vec<u64> = outs.iter().flat_map(|(ids, _)| ids.clone()).collect();
    all.sort_unstable();
    assert_eq!(all.len(), 4000);
    for (r, (_, w)) in outs.iter().enumerate() {
        assert!((w - plan.loads[r]).abs() < 1e-6, "rank {r} load {w} != plan {}", plan.loads[r]);
    }
    assert!(rep.total_msgs > 0);
}

/// Mesh pipeline: refine → centroids → partition → dual-graph edge cut
/// sane, and Hilbert-like cuts ≤ Morton on average.
#[test]
fn mesh_refinement_partition_quality() {
    let mesh = SimplexMesh::unit_square_tri(24);
    let mut drv = RefinementDriver::new(mesh, 5);
    for _ in 0..6 {
        drv.step();
    }
    let cents = drv.mesh.centroids();
    let edges = drv.mesh.dual_edges();
    let parts = 8;
    let mut cuts = std::collections::HashMap::new();
    for curve in [Curve::Morton, Curve::HilbertLike] {
        let cfg = PartitionConfig { parts, curve, ..Default::default() };
        let plan = Partitioner::new(cfg).partition(&cents);
        // Weighted balance: pairwise diff within two element weights
        // (each boundary can be off by up to wmax/2 on both sides).
        let wmax = cents.weights.iter().copied().fold(0.0f32, f32::max) as f64;
        assert!(plan.max_load_diff() <= 2.0 * wmax + 1e-6, "diff {}", plan.max_load_diff());
        let (total, max_cut, max_deg) = edge_cut_metrics(&edges, &plan.part_of, parts);
        assert!(total > 0 && max_deg <= parts - 1);
        cuts.insert(format!("{curve}"), max_cut);
    }
    // Locality: hilbert-like should not be dramatically worse.
    assert!(
        (cuts["hilbert-like"] as f64) <= 1.5 * cuts["morton"] as f64,
        "hilbert cut {} vs morton {}",
        cuts["hilbert-like"],
        cuts["morton"]
    );
}

/// Distributed partition under clustered skew: median splitters keep
/// per-rank loads within the leaf-granular knapsack bound, and the
/// cross-rank key order is total (§III-C invariant).
#[test]
fn distributed_partition_clustered_median() {
    let global = PointSet::clustered(3000, 3, 0.7, 17);
    let p = 5;
    let (outs, _) = run_ranks(p, CostModel::default(), |ctx| {
        let idx: Vec<u32> =
            (0..global.len() as u32).filter(|i| (*i as usize) % p == ctx.rank).collect();
        let local = global.gather(&idx);
        let cfg = PartitionConfig {
            splitter: SplitterConfig::uniform(SplitterKind::MedianSort),
            ..Default::default()
        };
        let dp = distributed_partition(ctx, &local, &cfg, 4 * p);
        (dp.local.ids.clone(), dp.keys.clone())
    });
    let mut all: Vec<u64> = outs.iter().flat_map(|(ids, _)| ids.clone()).collect();
    all.sort_unstable();
    assert_eq!(all, (0..3000).collect::<Vec<u64>>());
    for i in 0..p - 1 {
        if let (Some(a), Some(b)) = (outs[i].1.iter().max(), outs[i + 1].1.iter().min()) {
            assert!(a < b, "rank key order violated between {i} and {}", i + 1);
        }
    }
}

/// The query router on top of a partitioned, migrated dataset: every
/// stored point findable; k-NN recall positive.
#[test]
fn query_router_over_partitioned_data() {
    let ps = PointSet::uniform(3000, 3, 19);
    let mut cfg = SplitterConfig::uniform(SplitterKind::Midpoint);
    cfg.dim_rule = DimRule::Cycle;
    let mut tree = KdTreeBuilder::new()
        .bucket_size(16)
        .splitter(cfg)
        .domain(BoundingBox::unit(3))
        .threads(2)
        .build(&ps);
    assign_sfc(&mut tree, Curve::Morton);
    let idx = BucketIndex::from_tree(&tree, BoundingBox::unit(3));
    let mut router = QueryRouter::new(&ps, &idx, 3);
    let mut expect = Vec::new();
    for i in (0..3000).step_by(101) {
        router.submit(Query::Locate { coords: ps.point(i).to_vec(), eps: 1e-12 });
        expect.push(i as u32);
    }
    router.submit(Query::Knn { coords: vec![0.5, 0.5, 0.5], k: 5, cutoff: 2 });
    let results = router.flush();
    for (pos, &e) in expect.iter().enumerate() {
        assert_eq!(results[pos].1, QueryResult::Located(Some(e)));
    }
    match &results.last().unwrap().1 {
        QueryResult::Neighbors(nn) => {
            assert_eq!(nn.len(), 5);
            assert!(nn.windows(2).all(|w| w[0].dist2 <= w[1].dist2));
        }
        other => panic!("expected neighbors, got {other:?}"),
    }
}

/// Full §V-B flow: graph → partitions → metrics shape → distributed
/// PageRank matches the sequential oracle under both partitions.
#[test]
fn graph_pipeline_pagerank_parity() {
    let adj = rmat(RmatParams::graph500(9, 8.0), 29);
    let m = transition_matrix(&adj);
    let p = 4;
    let iters = 5;
    let damping = 0.85;
    let (pr_ref, _) = pagerank_seq(&m.to_csr(), damping, iters, 0.0);

    for part in [rowwise_partition(&m, p), sfc_partition(&m, p, Curve::Morton, 1).0] {
        let n = m.n_rows;
        let (outs, _) = run_ranks(p, CostModel::default(), |ctx| {
            let local = LocalMatrix::shard(&m, &part, ctx.rank);
            let plan = build_plan(ctx, &local);
            let owned = owned_range(n, p, ctx.rank);
            let mut x = vec![1.0 / n as f64; (owned.1 - owned.0) as usize];
            for _ in 0..iters {
                let mut y = spmv_step(ctx, &plan, &x);
                for v in y.iter_mut() {
                    *v = damping * *v + (1.0 - damping) / n as f64;
                }
                let total = ctx.allreduce1(ReduceOp::Sum, y.iter().sum());
                for v in y.iter_mut() {
                    *v /= total;
                }
                x = y;
            }
            (owned, x)
        });
        let mut got = vec![0.0f64; n];
        for (owned, x) in outs {
            got[owned.0 as usize..owned.1 as usize].copy_from_slice(&x);
        }
        let err: f64 = got.iter().zip(&pr_ref).map(|(a, b)| (a - b).abs()).sum();
        assert!(err < 1e-9, "L1 err {err}");
    }

    // Metrics shape: load balance is the invariant at any p (the edge-cut
    // advantage needs p ≥ ~32 on power-law graphs and is asserted in the
    // metrics unit tests at p = 64).
    let row = spmv_metrics(&m, &rowwise_partition(&m, p), p);
    let (sp, _) = sfc_partition(&m, p, Curve::Morton, 1);
    let sfc = spmv_metrics(&m, &sp, p);
    assert!(sfc.max_load <= row.max_load);
    assert!(sfc.max_load <= sfc.avg_load.ceil() as u64 + 1);
}

/// Surface-to-volume quality: partitions of clustered data have finite,
/// reasonable ratios and Hilbert-like ≤ Morton on average.
#[test]
fn surface_volume_hilbert_advantage() {
    let ps = PointSet::uniform(6000, 2, 23);
    let parts = 16;
    let sv = |curve| {
        let cfg = PartitionConfig { parts, curve, ..Default::default() };
        let plan = Partitioner::new(cfg).partition(&ps);
        surface_volume_summary(&surface_to_volume(&ps, &plan.part_of, parts)).0
    };
    let m = sv(Curve::Morton);
    let h = sv(Curve::HilbertLike);
    // Same tree, different slicing: Hilbert-like wins on average but not
    // on every seed; bound the regression and rely on the traversal
    // locality tests (avg hop, jump counts) for the strict claim.
    assert!(h <= m * 1.2, "hilbert sv {h} vs morton {m}");
}

/// Dynamic driver conserves points and keeps buckets within bounds
/// across a full Algorithm-3 run.
#[test]
fn dynamic_driver_invariants() {
    let ps = PointSet::uniform(1500, 3, 31);
    let s = sfc_part::kdtree::dynamic_driver::run_dynamic(&ps, 120, 20, 3, 16, 41);
    assert!(s.final_points > 1500); // net growth with delete_frac 0.3
    assert!(s.insert_secs > 0.0 && s.adjust_secs > 0.0);
}
