//! Backend-conformance suite: every [`PartitionBackend`] must satisfy
//! the same contract — each id assigned exactly once, parts in bounds,
//! loads consistent with the weights, and bit-identical output for any
//! thread count — and the `SfcKnapsack` backend must be bit-identical
//! to the pre-trait entry points it wraps.

use sfc_part::geom::point::PointSet;
use sfc_part::partition::distributed::distributed_partition;
use sfc_part::partition::knapsack::part_loads;
use sfc_part::partition::partitioner::{PartitionConfig, Partitioner};
use sfc_part::partition::{make_backend, BackendKind};
use sfc_part::runtime_sim::{run_ranks_threaded, CostModel};

/// Rank counts to sweep: `SFC_TEST_RANKS=2` (or a comma list) narrows
/// the sweep — CI uses it to run the distributed suite at 2 and 8
/// simulated ranks.
fn rank_sweep() -> Vec<usize> {
    match std::env::var("SFC_TEST_RANKS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("SFC_TEST_RANKS wants integers"))
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    }
}

/// The three input shapes every backend must survive: smooth, skewed,
/// and duplicate-heavy (zero-extent clusters), all with mixed weights.
fn datasets() -> Vec<(&'static str, PointSet)> {
    let mut dup = PointSet::new(2);
    for i in 0..600u64 {
        let w = 1.0 + (i % 7) as f32 * 0.5;
        if i < 450 {
            dup.push(&[0.3, 0.7], i, w);
        } else {
            let t = (i - 450) as f64 / 150.0;
            dup.push(&[0.8 * t + 0.1, 0.2 + 0.6 * t], i, w);
        }
    }
    vec![
        ("uniform", PointSet::uniform_weighted(900, 3, 4.0, 11)),
        ("clustered", PointSet::clustered(900, 2, 0.7, 23)),
        ("duplicate-heavy", dup),
    ]
}

const BACKENDS: [BackendKind; 3] =
    [BackendKind::Sfc, BackendKind::KMeans, BackendKind::Rectilinear];

/// Shared-memory contract: `partition` yields a valid permutation,
/// in-bounds parts, loads that equal the per-part weight sums, and the
/// same bits for 1 and 4 threads.
#[test]
fn backend_partition_conformance() {
    for (dname, ps) in datasets() {
        for kind in BACKENDS {
            let backend = make_backend(kind);
            for &parts in &rank_sweep() {
                let run = |threads: usize| {
                    let cfg = PartitionConfig { parts, threads, ..Default::default() };
                    backend.partition(&ps, &cfg)
                };
                let plan = run(1);
                let tag = format!("{dname}/{}/p={parts}", kind.name());
                // perm is a permutation of 0..n, consistent with ids_in_order.
                let mut sorted = plan.perm.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..ps.len() as u32).collect::<Vec<u32>>(), "{tag}: perm");
                assert_eq!(plan.ids_in_order.len(), ps.len(), "{tag}: ids_in_order");
                for (j, &pi) in plan.perm.iter().enumerate() {
                    assert_eq!(plan.ids_in_order[j], ps.ids[pi as usize], "{tag}: id order");
                }
                // Parts in bounds, loads = exact per-part weight sums.
                assert_eq!(plan.part_of.len(), ps.len(), "{tag}: part_of len");
                assert!(plan.part_of.iter().all(|&q| (q as usize) < parts), "{tag}: bounds");
                assert_eq!(plan.loads, part_loads(&plan.part_of, &ps.weights, parts), "{tag}: loads");
                // Thread invariance is bitwise.
                let plan4 = run(4);
                assert_eq!(plan.perm, plan4.perm, "{tag}: perm diverged at 4 threads");
                assert_eq!(plan.part_of, plan4.part_of, "{tag}: part_of diverged");
                assert_eq!(plan.loads, plan4.loads, "{tag}: loads diverged");
            }
        }
    }
}

/// Distributed contract: `partition_dist` conserves the id multiset,
/// conserves total weight across ranks, and is bit-identical for 1 and
/// 2 threads per rank.
#[test]
fn backend_partition_dist_conformance() {
    for (dname, ps) in datasets() {
        let total_w: f64 = ps.weights.iter().map(|&w| w as f64).sum();
        for kind in BACKENDS {
            for &p in &rank_sweep() {
                let backend = make_backend(kind);
                let backend = &*backend;
                let run = |tpr: usize| {
                    run_ranks_threaded(p, tpr, CostModel::default(), |ctx| {
                        let local = ps.mod_shard(ctx.rank, ctx.n_ranks);
                        let cfg = PartitionConfig::default();
                        let dp = backend.partition_dist(ctx, &local, &cfg, 4 * p);
                        let load: f64 =
                            dp.local.weights.iter().map(|&w| w as f64).sum();
                        (dp.local.ids.clone(), dp.keys.clone(), load)
                    })
                    .0
                };
                let outs = run(1);
                let tag = format!("{dname}/{}/p={p}", kind.name());
                // Conservation: every id lands on exactly one rank.
                let mut all: Vec<u64> =
                    outs.iter().flat_map(|(ids, _, _)| ids.iter().copied()).collect();
                all.sort_unstable();
                assert_eq!(all, ps.ids.iter().copied().collect::<Vec<u64>>(), "{tag}: ids");
                // Keys travel with the points.
                for (ids, keys, _) in &outs {
                    assert_eq!(ids.len(), keys.len(), "{tag}: keys len");
                }
                // Weight conservation across the migration.
                let sum: f64 = outs.iter().map(|(_, _, l)| *l).sum();
                assert!(
                    (sum - total_w).abs() <= 1e-6 * total_w.max(1.0),
                    "{tag}: weight {sum} != {total_w}"
                );
                // Threads-per-rank invariance is bitwise.
                assert_eq!(outs, run(2), "{tag}: output diverged at 2 threads/rank");
            }
        }
    }
}

/// The refactor's non-negotiable: `SfcKnapsack` behind the trait is
/// bit-identical to calling `Partitioner` / `distributed_partition`
/// directly, so moving callers onto the trait changed nothing.
#[test]
fn sfc_backend_is_bit_identical_to_direct_pipeline() {
    let backend = make_backend(BackendKind::Sfc);
    for (dname, ps) in datasets() {
        for &parts in &rank_sweep() {
            let cfg = PartitionConfig { parts, ..Default::default() };
            let via_trait = backend.partition(&ps, &cfg);
            let direct = Partitioner::new(cfg.clone()).partition(&ps);
            assert_eq!(via_trait.perm, direct.perm, "{dname}/p={parts}: perm");
            assert_eq!(via_trait.part_of, direct.part_of, "{dname}/p={parts}: part_of");
            assert_eq!(via_trait.loads, direct.loads, "{dname}/p={parts}: loads");
            assert_eq!(via_trait.ids_in_order, direct.ids_in_order, "{dname}/p={parts}: ids");
        }
    }
    let ps = PointSet::clustered(1200, 3, 0.5, 39);
    for &p in &rank_sweep() {
        let backend = &*backend;
        let both = run_ranks_threaded(p, 1, CostModel::default(), |ctx| {
            let local = ps.mod_shard(ctx.rank, ctx.n_ranks);
            let cfg = PartitionConfig::default();
            let via = backend.partition_dist(ctx, &local, &cfg, 4 * p);
            let direct = distributed_partition(ctx, &local, &cfg, 4 * p);
            (
                via.local.ids == direct.local.ids
                    && via.keys == direct.keys
                    && via.owned_leaves == direct.owned_leaves,
                via.local.len(),
            )
        })
        .0;
        assert!(both.iter().all(|(same, _)| *same), "p={p}: trait != direct distributed");
        let n: usize = both.iter().map(|(_, n)| *n).sum();
        assert_eq!(n, ps.len(), "p={p}: points lost");
    }
}
