//! Negative tests for the debug-build collective-congruence checker:
//! a rank calling the *wrong* collective (wrong op, wrong lane layout,
//! or skipping a barrier) must abort the whole run with a panic naming
//! both sides' signatures — not deadlock on mismatched tags.
//!
//! The checker only exists under `debug_assertions`, so the whole
//! module is gated; release test runs compile this file to nothing.

#![cfg(debug_assertions)]

use sfc_part::runtime_sim::collectives::ReduceOp;
use sfc_part::runtime_sim::{Fabric, RankCtx};

/// Render a panic payload as text.
fn payload_str(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Run two rank bodies on a 2-rank fabric with hand-spawned threads
/// (not `run_ranks`, whose scope would swallow the panic payloads) and
/// return each rank's panic message, `None` if it completed.
fn run_two(
    f: impl FnOnce(&mut RankCtx) + Send,
    g: impl FnOnce(&mut RankCtx) + Send,
) -> [Option<String>; 2] {
    let fabric = Fabric::new(2);
    let fab = &fabric;
    std::thread::scope(|s| {
        let h0 = s.spawn(move || {
            let mut ctx = RankCtx::new(0, 2, 1, fab);
            f(&mut ctx);
        });
        let h1 = s.spawn(move || {
            let mut ctx = RankCtx::new(1, 2, 1, fab);
            g(&mut ctx);
        });
        [h0.join().err().map(payload_str), h1.join().err().map(payload_str)]
    })
}

fn assert_divergence_names_both(msgs: &[Option<String>; 2], a: &str, b: &str) {
    // Every rank must die (no hang, no silent completion)...
    assert!(msgs.iter().all(|m| m.is_some()), "both ranks should panic: {msgs:?}");
    // ...and at least one panic carries the both-sides diagnostic.
    let diagnosed = msgs.iter().flatten().any(|m| {
        m.contains("collective congruence violation") && m.contains(a) && m.contains(b)
    });
    assert!(diagnosed, "no diagnostic naming `{a}` and `{b}`: {msgs:?}");
}

#[test]
fn congruent_sequence_completes() {
    let body = |ctx: &mut RankCtx| {
        ctx.barrier();
        let s = ctx.allreduce_f64(ReduceOp::Sum, &[1.5])[0];
        assert_eq!(s, 3.0);
        let e = ctx.exscan_u64(ctx.rank as u64 + 1);
        assert_eq!(e, ctx.rank as u64); // exscan of [1, 1+...]
    };
    let msgs = run_two(body, body);
    assert_eq!(msgs, [None, None], "congruent ranks must not panic");
}

#[test]
fn wrong_reduce_op_panics_with_both_signatures() {
    let msgs = run_two(
        |ctx| {
            ctx.allreduce_f64(ReduceOp::Sum, &[1.0]);
        },
        |ctx| {
            ctx.allreduce_f64(ReduceOp::Max, &[1.0]);
        },
    );
    assert_divergence_names_both(&msgs, "op=Sum", "op=Max");
}

#[test]
fn wrong_lane_layout_panics_with_both_signatures() {
    let msgs = run_two(
        |ctx| {
            ctx.allreduce_f64(ReduceOp::Sum, &[1.0]);
        },
        |ctx| {
            ctx.allreduce_f64(ReduceOp::Sum, &[1.0, 2.0]);
        },
    );
    assert_divergence_names_both(&msgs, "lanes=1", "lanes=2");
}

#[test]
fn mixed_section_layout_panics_with_both_signatures() {
    let msgs = run_two(
        |ctx| {
            ctx.allreduce_multi(&[sfc_part::runtime_sim::collectives::Section::U64(
                ReduceOp::Sum,
                &[1],
            )]);
        },
        |ctx| {
            ctx.allreduce_multi(&[sfc_part::runtime_sim::collectives::Section::F64(
                ReduceOp::Sum,
                &[1.0],
            )]);
        },
    );
    assert_divergence_names_both(&msgs, "u64[1]", "f64[1]");
}

#[test]
fn skipped_barrier_panics_instead_of_hanging() {
    // Without the checker this is a *deadlock*: rank 0's barrier
    // consumes rank 1's allreduce traffic (tag epochs alias), and
    // rank 0 then blocks forever in its own allreduce. The checker
    // turns it into an immediate two-sided diagnostic.
    let msgs = run_two(
        |ctx| {
            ctx.barrier();
            ctx.allreduce_f64(ReduceOp::Sum, &[0.5]);
        },
        |ctx| {
            ctx.allreduce_f64(ReduceOp::Sum, &[0.5]);
        },
    );
    assert_divergence_names_both(&msgs, "barrier", "allreduce_f64");
}

#[test]
fn peer_panic_message_names_root_cause() {
    // The rank that dies while *blocked* (fabric poisoned by the
    // diverging rank) must still see the congruence diagnostic.
    let msgs = run_two(
        |ctx| {
            ctx.barrier();
            ctx.allreduce_f64(ReduceOp::Sum, &[0.5]);
        },
        |ctx| {
            ctx.allreduce_f64(ReduceOp::Sum, &[0.5]);
        },
    );
    for m in msgs.iter().flatten() {
        assert!(
            m.contains("collective congruence violation"),
            "every rank's panic should name the cause: {m}"
        );
    }
}

#[test]
fn divergence_is_recorded_on_the_fabric() {
    let fabric = Fabric::new(2);
    let fab = &fabric;
    std::thread::scope(|s| {
        let h0 = s.spawn(move || {
            let mut ctx = RankCtx::new(0, 2, 1, fab);
            ctx.barrier();
        });
        let h1 = s.spawn(move || {
            let mut ctx = RankCtx::new(1, 2, 1, fab);
            ctx.exscan_f64(1.0);
        });
        let _ = h0.join();
        let _ = h1.join();
    });
    let d = fabric.divergence().expect("divergence should be recorded");
    assert!(d.contains("barrier") && d.contains("exscan_f64"), "{d}");
}
