//! Failure-injection and stress tests for the simulated runtime: the
//! fabric must fail fast (poison) instead of deadlocking when a rank
//! dies, collectives must survive adversarial sizes, and the migration
//! path must hold under fuzzed destinations.

use sfc_part::geom::point::PointSet;
use sfc_part::migrate::transfer_t_l_t;
use sfc_part::runtime_sim::collectives::ReduceOp;
use sfc_part::runtime_sim::{run_ranks, CostModel};
use sfc_part::util::prop::forall;

/// A rank that panics mid-collective must abort the whole run (poisoned
/// fabric), not hang it.
#[test]
fn rank_panic_poisons_instead_of_deadlocking() {
    let result = std::panic::catch_unwind(|| {
        run_ranks(4, CostModel::default(), |ctx| {
            if ctx.rank == 2 {
                panic!("injected rank failure");
            }
            // Other ranks block in a collective rank 2 never joins.
            ctx.allreduce1(ReduceOp::Sum, 1.0)
        })
    });
    assert!(result.is_err(), "run_ranks should propagate the rank panic");
}

/// Same for a rank dying inside the bounded all-to-all.
#[test]
fn rank_panic_in_alltoall_aborts() {
    let result = std::panic::catch_unwind(|| {
        run_ranks(3, CostModel::default(), |ctx| {
            if ctx.rank == 0 {
                panic!("boom");
            }
            let bufs: Vec<Vec<u8>> = (0..3).map(|_| vec![1u8; 100]).collect();
            ctx.alltoallv_rounds(bufs, 16)
        })
    });
    assert!(result.is_err());
}

/// Collectives with zero-length and wildly uneven payloads.
#[test]
fn collectives_survive_adversarial_sizes() {
    let (outs, _) = run_ranks(5, CostModel::default(), |ctx| {
        // Rank r contributes a buffer of r^3 bytes to everyone.
        let bufs: Vec<Vec<u8>> =
            (0..5).map(|_| vec![ctx.rank as u8; ctx.rank * ctx.rank * ctx.rank]).collect();
        let got = ctx.alltoallv_rounds(bufs, 7); // prime cap -> ragged rounds
        got.iter().map(|b| b.len()).collect::<Vec<_>>()
    });
    for got in outs {
        assert_eq!(got, vec![0, 1, 8, 27, 64]);
    }
}

/// Fuzzed migration: arbitrary destination assignments conserve points.
#[test]
fn fuzzed_migration_conserves_points() {
    forall("migration-conservation", 12, |g| {
        let p = g.usize_in(2, 6);
        let n_per = g.usize_in(1, 80);
        let dim = g.usize_in(2, 4);
        let max_msg = 1 << g.usize_in(6, 14);
        // Destination table per (rank, local index).
        let dests: Vec<Vec<u32>> = (0..p)
            .map(|_| (0..n_per).map(|_| g.u64_below(p as u64) as u32).collect())
            .collect();
        let (outs, rep) = run_ranks(p, CostModel::default(), |ctx| {
            let mut ps = PointSet::new(dim);
            for i in 0..n_per {
                let coords: Vec<f64> = (0..dim).map(|k| (i * dim + k) as f64 * 0.001).collect();
                ps.push(&coords, (ctx.rank * 10_000 + i) as u64, 1.0);
            }
            let got = transfer_t_l_t(ctx, &ps, &dests[ctx.rank], max_msg);
            got.ids
        });
        let total: usize = outs.iter().map(|ids| ids.len()).sum();
        let mut all: Vec<u64> = outs.iter().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        let ok = total == p * n_per && all.len() == total && rep.max_msg_bytes <= max_msg as u64;
        (
            ok,
            format!(
                "p={p} n_per={n_per} total={total} uniq={} max_msg={} cap={max_msg}",
                all.len(),
                rep.max_msg_bytes
            ),
        )
    });
}

/// Reduce-scatter with ragged counts across many rank counts.
#[test]
fn reduce_scatter_ragged_counts() {
    for p in [2usize, 3, 5, 8] {
        let counts: Vec<usize> = (0..p).map(|i| i + 1).collect();
        let total: usize = counts.iter().sum();
        let counts2 = counts.clone();
        let (outs, _) = run_ranks(p, CostModel::default(), move |ctx| {
            let data: Vec<f64> = (0..total).map(|i| (i + ctx.rank) as f64).collect();
            ctx.reduce_scatter_f64(&data, &counts2)
        });
        // Position j accumulates sum over ranks of (j + rank).
        let rank_sum: f64 = (0..p).map(|r| r as f64).sum();
        let mut off = 0;
        for (r, out) in outs.iter().enumerate() {
            assert_eq!(out.len(), counts[r]);
            for (k, v) in out.iter().enumerate() {
                let j = (off + k) as f64;
                assert_eq!(*v, j * p as f64 + rank_sum, "rank {r} pos {k}");
            }
            off += counts[r];
        }
    }
}

/// Dynamic forest under heavy random churn keeps its invariants — the
/// long-running soak the paper's 1000-iteration runs imply.
#[test]
fn dynamic_forest_soak() {
    use sfc_part::geom::dist::DynamicStream;
    use sfc_part::kdtree::dynamic::DynForest;
    let ps = PointSet::uniform(3000, 3, 55);
    let mut f = DynForest::from_points(&ps, 16, 8, 3);
    let mut stream = DynamicStream::new(3, 3000, 9);
    stream.delete_frac = 0.45;
    for round in 0..20 {
        let ids = f.all_ids();
        let (ins, del_ids) = stream.step(150, &ids);
        let del_set: std::collections::HashSet<u64> = del_ids.iter().copied().collect();
        let mut dels = Vec::new();
        for t in &f.subtrees {
            for b in &t.buckets {
                for (i, &id) in b.ids.iter().enumerate() {
                    if del_set.contains(&id) {
                        dels.push((b.coords[i * 3..(i + 1) * 3].to_vec(), id));
                    }
                }
            }
        }
        f.insert_delete_parallel(&ins, &dels, 3);
        if round % 4 == 0 {
            f.adjustments_parallel(3);
            for t in &f.subtrees {
                t.check_invariants().unwrap_or_else(|e| panic!("round {round}: {e}"));
            }
        }
    }
    assert!(f.n_points() > 3000);
}
