//! Property suite for the distributed query engine
//! ([`DistQueryEngine::serve`]):
//!
//! * **locate** — distributed answers equal the single-set oracle
//!   (minimum global id within `eps`) for every probe;
//! * **kNN** — with unbounded spill, distributed answers equal
//!   `knn_exact_by_id` bit-for-bit (ids and `dist2` bits); capping
//!   `spill_max_ranks` degrades *monotonically* (a larger cap is never
//!   worse at any result position) and a cap of 0 puts zero spill
//!   forwardings on the wire;
//! * **1:1** — every query in a batch receives exactly one answer slot,
//!   in issue order;
//! * **determinism** — answers are bit-identical across threads-per-rank
//!   and across how the stream is chunked into batches;
//! * **accounting** — each `serve` costs 3 collective exchanges and a
//!   tag-epoch count *independent of the number of queries* (no
//!   per-query collectives);
//! * **sessions** — serving interleaved with `repartition` + `refresh`
//!   stays exact against an independently evolved replica, and a no-op
//!   step refreshes routing without rebuilding the local index.
//!
//! Sweeps run over `SFC_TEST_RANKS` × dataset shapes (uniform,
//! clustered, duplicate-heavy) × thread counts, mirroring the other
//! distributed suites so CI partitions them identically.

use sfc_part::geom::point::PointSet;
use sfc_part::partition::distributed::{
    step_ranks, DistSession, SessionConfig, UpdateBatch,
};
use sfc_part::partition::partitioner::PartitionConfig;
use sfc_part::partition::scenario::{Scenario, ScenarioKind};
use sfc_part::query::distributed::{DistQueryEngine, EngineConfig, QueryBatch, ServeStats};
use sfc_part::query::knn::{knn_exact_by_id, IdNeighbor};
use sfc_part::runtime_sim::{run_ranks_threaded, CostModel};
use sfc_part::util::prop::forall;
use sfc_part::util::rng::{Rng, SplitMix64};

const EPS: f64 = 1e-12;

/// Rank counts to sweep (`SFC_TEST_RANKS=2` or a comma list narrows it;
/// CI partitions {1,4} / {2} / {8}).
fn rank_sweep() -> Vec<usize> {
    match std::env::var("SFC_TEST_RANKS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("SFC_TEST_RANKS wants integers"))
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    }
}

/// Datasets: 0 = uniform, 1 = clustered, 2 = duplicate-heavy (every
/// coordinate appears ~4× under distinct ids — the placement-ambiguity
/// stressor for min-id locate and (dist2, id) tie-breaks).
fn dataset(kind: usize, n: usize, seed: u32) -> PointSet {
    match kind {
        0 => PointSet::uniform(n, 3, seed),
        1 => PointSet::clustered(n, 3, 0.7, seed),
        _ => {
            let base = PointSet::uniform(n.div_ceil(4).max(1), 3, seed);
            let mut ps = PointSet::new(3);
            let mut id = 0u64;
            'fill: for _ in 0..4 {
                for i in 0..base.len() {
                    if ps.len() == n {
                        break 'fill;
                    }
                    ps.push(base.point(i), id, 1.0);
                    id += 1;
                }
            }
            ps
        }
    }
}

/// A dealt query stream: per rank the stored points it probes (locate),
/// the coordinates it asks kNN for (half stored points, for distance
/// ties; half fresh), and the same stream chunked into serve batches.
struct Dealt {
    batches: Vec<Vec<QueryBatch>>,
    loc_probes: Vec<Vec<usize>>,
    knn_probes: Vec<Vec<Vec<f64>>>,
}

/// Deal `n_loc` + `n_knn` queries round-robin over `p` issuing ranks,
/// chunked into epochs of ≤ `batch` queries. Every rank gets the same
/// epoch count (`serve` is collective; trailing batches may be empty)
/// and the per-rank probe order is independent of `batch`.
fn deal(
    global: &PointSet,
    p: usize,
    n_loc: usize,
    n_knn: usize,
    k: usize,
    batch: usize,
    seed: u64,
) -> Dealt {
    let mut per_rank: Vec<(Vec<usize>, Vec<Vec<f64>>)> = Vec::with_capacity(p);
    let mut n_epochs = 1usize;
    for r in 0..p {
        let mut rng = SplitMix64::new(seed.wrapping_mul(31).wrapping_add(r as u64));
        let my_loc = n_loc / p + usize::from(r < n_loc % p);
        let my_knn = n_knn / p + usize::from(r < n_knn % p);
        let locs: Vec<usize> =
            (0..my_loc).map(|_| rng.below(global.len() as u64) as usize).collect();
        let knns: Vec<Vec<f64>> = (0..my_knn)
            .map(|_| {
                if rng.below(2) == 0 {
                    global.point(rng.below(global.len() as u64) as usize).to_vec()
                } else {
                    (0..global.dim).map(|_| rng.next_f64()).collect()
                }
            })
            .collect();
        n_epochs = n_epochs.max((my_loc + my_knn).div_ceil(batch));
        per_rank.push((locs, knns));
    }
    let mut batches = Vec::with_capacity(p);
    let mut loc_probes = Vec::with_capacity(p);
    let mut knn_probes = Vec::with_capacity(p);
    for (locs, knns) in per_rank {
        let mut eps_b = Vec::with_capacity(n_epochs);
        let (mut li, mut ki) = (0usize, 0usize);
        for _ in 0..n_epochs {
            let mut b = QueryBatch::new(global.dim, EPS, k);
            let mut room = batch;
            while room > 0 && li < locs.len() {
                b.push_locate(global.point(locs[li]));
                li += 1;
                room -= 1;
            }
            while room > 0 && ki < knns.len() {
                b.push_knn(&knns[ki]);
                ki += 1;
                room -= 1;
            }
            eps_b.push(b);
        }
        assert!(li == locs.len() && ki == knns.len(), "dealing under-filled the epochs");
        batches.push(eps_b);
        loc_probes.push(locs);
        knn_probes.push(knns);
    }
    Dealt { batches, loc_probes, knn_probes }
}

/// Per-rank served output: concatenated locate answers, concatenated
/// kNN answers (both in issue order), per-epoch stats.
type RankOut = (Vec<Option<u64>>, Vec<Vec<IdNeighbor>>, Vec<ServeStats>);

/// Create sessions + engines at `p` ranks and serve every dealt epoch.
fn serve_dealt(
    global: &PointSet,
    p: usize,
    tpr: usize,
    ecfg: EngineConfig,
    dealt: &Dealt,
) -> Vec<RankOut> {
    let cfg = PartitionConfig::default();
    let (built, _) = run_ranks_threaded(p, tpr, CostModel::default(), |ctx| {
        let local = global.mod_shard(ctx.rank, ctx.n_ranks);
        let sess = DistSession::create(ctx, &local, &cfg, 4 * p, SessionConfig::default());
        let eng = DistQueryEngine::new(&sess, ecfg, ctx.threads);
        (sess, eng)
    });
    let mut states = built;
    let n_epochs = dealt.batches[0].len();
    let mut out: Vec<RankOut> = (0..p).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
    for e in 0..n_epochs {
        let bt = &dealt.batches;
        let (next, res, _) =
            step_ranks(p, tpr, CostModel::default(), states, |ctx, (sess, eng)| {
                let r = eng.serve(ctx, &sess, &bt[ctx.rank][e]);
                ((sess, eng), r)
            });
        states = next;
        for (r, (ans, st)) in res.into_iter().enumerate() {
            // 1:1 — one answer slot per query, in issue order.
            assert_eq!(ans.locate.len(), bt[r][e].n_locate());
            assert_eq!(ans.knn.len(), bt[r][e].n_knn());
            out[r].0.extend(ans.locate);
            out[r].1.extend(ans.knn);
            out[r].2.push(st);
        }
    }
    out
}

/// Single-set locate oracle: minimum global id within `eps` of `q`.
fn locate_oracle(ps: &PointSet, q: &[f64]) -> Option<u64> {
    let e2 = EPS * EPS;
    (0..ps.len()).filter(|&i| ps.dist2_to(i, q) <= e2).map(|i| ps.ids[i]).min()
}

fn same_neighbors(a: &[IdNeighbor], b: &[IdNeighbor]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.id == y.id && x.dist2.to_bits() == y.dist2.to_bits())
}

#[test]
fn prop_distributed_answers_match_single_set_oracles() {
    forall("distributed-query-oracles", 2, |g| {
        let n = g.usize_in(500, 900);
        let seed = g.u64_below(1000) as u32;
        let k = g.usize_in(1, 6);
        for kind in 0..3usize {
            let ps = dataset(kind, n, seed);
            for &p in &rank_sweep() {
                let dealt = deal(&ps, p, 96, 32, k, 40, 7 + kind as u64);
                let outs = serve_dealt(&ps, p, 1, EngineConfig::default(), &dealt);
                for r in 0..p {
                    let (locs, knns, _) = &outs[r];
                    for (j, &pi) in dealt.loc_probes[r].iter().enumerate() {
                        let want = locate_oracle(&ps, ps.point(pi));
                        if locs[j] != want {
                            return (
                                false,
                                format!(
                                    "kind={kind} p={p} rank={r} locate[{j}]: got {:?} want {want:?}",
                                    locs[j]
                                ),
                            );
                        }
                    }
                    for (j, q) in dealt.knn_probes[r].iter().enumerate() {
                        let want = knn_exact_by_id(&ps, q, k);
                        if !same_neighbors(&knns[j], &want) {
                            return (
                                false,
                                format!(
                                    "kind={kind} p={p} rank={r} knn[{j}]: got {:?} want {want:?}",
                                    knns[j]
                                ),
                            );
                        }
                    }
                }
            }
        }
        (true, String::new())
    });
}

#[test]
fn prop_answers_bit_identical_across_threads_and_batching() {
    let ps = dataset(1, 800, 5);
    let k = 4;
    for &p in &rank_sweep() {
        let dealt = deal(&ps, p, 80, 24, k, 33, 11);
        let base = serve_dealt(&ps, p, 1, EngineConfig::default(), &dealt);
        for tpr in [2usize, 5] {
            let alt = serve_dealt(&ps, p, tpr, EngineConfig::default(), &dealt);
            for r in 0..p {
                assert_eq!(alt[r].0, base[r].0, "locate diverged at p={p} tpr={tpr} rank={r}");
                assert_eq!(alt[r].1.len(), base[r].1.len());
                for (a, b) in alt[r].1.iter().zip(&base[r].1) {
                    assert!(same_neighbors(a, b), "knn diverged at p={p} tpr={tpr} rank={r}");
                }
            }
        }
        // Re-chunking the same stream into tiny batches changes the
        // epoch structure but not a single answer bit.
        let fine = deal(&ps, p, 80, 24, k, 7, 11);
        let alt = serve_dealt(&ps, p, 3, EngineConfig::default(), &fine);
        for r in 0..p {
            assert_eq!(alt[r].0, base[r].0, "locate changed under re-batching at p={p} rank={r}");
            for (a, b) in alt[r].1.iter().zip(&base[r].1) {
                assert!(same_neighbors(a, b), "knn changed under re-batching at p={p} rank={r}");
            }
        }
    }
}

#[test]
fn prop_spill_cap_is_monotone_and_unbounded_is_exact() {
    let ps = dataset(0, 700, 9);
    let k = 5;
    for &p in &rank_sweep() {
        let dealt = deal(&ps, p, 16, 48, k, 32, 13);
        let caps = [0usize, 1, usize::MAX];
        let runs: Vec<Vec<RankOut>> = caps
            .iter()
            .map(|&c| {
                let ecfg = EngineConfig { spill_max_ranks: c, ..EngineConfig::default() };
                serve_dealt(&ps, p, 1, ecfg, &dealt)
            })
            .collect();
        // Cap 0 = owner-only answers: nothing may be forwarded.
        let fwd0: u64 =
            runs[0].iter().flat_map(|r| r.2.iter()).map(|st| st.spill_forwards).sum();
        assert_eq!(fwd0, 0, "spill cap 0 still forwarded queries at p={p}");
        // Unbounded spill equals the exact single-set oracle.
        for r in 0..p {
            for (j, q) in dealt.knn_probes[r].iter().enumerate() {
                let want = knn_exact_by_id(&ps, q, k);
                assert!(
                    same_neighbors(&runs[2][r].1[j], &want),
                    "unbounded spill not exact at p={p} rank={r} q={j}"
                );
            }
        }
        // The documented recall bound: spill targets are nearest-first
        // truncations of one fixed order, so a larger cap consults a
        // superset of owners and its k-best dominates position-wise.
        for w in runs.windows(2) {
            for r in 0..p {
                for (small, big) in w[0][r].1.iter().zip(&w[1][r].1) {
                    assert!(big.len() >= small.len(), "larger cap returned fewer hits");
                    for (s, b) in small.iter().zip(big) {
                        assert!(
                            (b.dist2, b.id) <= (s.dist2, s.id),
                            "smaller spill cap beat a larger one at p={p} rank={r}"
                        );
                    }
                }
            }
        }
    }
}

/// Per-epoch stats of a locate/kNN run at fixed `p`, for the
/// collective-accounting assertions below.
fn stats_of(global: &PointSet, p: usize, n_loc: usize, n_knn: usize, k: usize) -> Vec<ServeStats> {
    let dealt = deal(global, p, n_loc, n_knn, k, n_loc + n_knn + 1, 17);
    let outs = serve_dealt(global, p, 1, EngineConfig::default(), &dealt);
    assert!(outs.iter().all(|o| o.2.len() == 1), "expected a single epoch");
    outs.into_iter().map(|o| o.2[0]).collect()
}

#[test]
fn serve_collective_cost_is_independent_of_batch_size() {
    let ps = dataset(0, 600, 3);
    let p = 4;
    // Locate-only: 8 vs 400 queries must cost identical tag epochs —
    // 3 exchanges (route, spill, return), no per-query collectives.
    let small = stats_of(&ps, p, 8, 0, 3);
    let large = stats_of(&ps, p, 400, 0, 3);
    for st in small.iter().chain(&large) {
        assert_eq!(st.exchanges, 3);
    }
    // Epochs are collective-congruent: equal across ranks…
    assert!(small.iter().all(|st| st.epochs == small[0].epochs));
    assert!(large.iter().all(|st| st.epochs == large[0].epochs));
    // …and independent of how many queries the batch carries.
    assert_eq!(small[0].epochs, large[0].epochs, "tag epochs scaled with the batch");
    // With k > |shard| every kNN probe forwards to all other ranks
    // (radius ∞), so both sizes exercise a non-empty spill round and
    // must still agree on epochs.
    let sk = stats_of(&ps, p, 8, 2, 200);
    let lk = stats_of(&ps, p, 400, 2, 200);
    assert!(sk.iter().map(|st| st.spill_forwards).sum::<u64>() >= 2 * (p as u64 - 1));
    assert_eq!(sk[0].epochs, lk[0].epochs, "spill round epochs scaled with the batch");
    assert!(sk.iter().all(|st| st.epochs == sk[0].epochs));
    // Conservation of answering: owner-side answer counts sum to the
    // issued total on both sides of the exchange.
    let issued: u64 = large.iter().map(|st| st.queries).sum();
    let answered: u64 = large.iter().map(|st| st.answered_owner).sum();
    assert_eq!(issued, answered);
}

#[test]
fn prop_serving_interleaves_with_repartition_steps() {
    let scen = Scenario::new(ScenarioKind::Hotspot);
    let k = 4;
    for &p in &rank_sweep() {
        let ps = dataset(0, 900, 21);
        let cfg = PartitionConfig::default();
        let (built, _) = run_ranks_threaded(p, 1, CostModel::default(), |ctx| {
            let local = ps.mod_shard(ctx.rank, ctx.n_ranks);
            let sess = DistSession::create(ctx, &local, &cfg, 4 * p, SessionConfig::default());
            let eng = DistQueryEngine::new(&sess, EngineConfig::default(), ctx.threads);
            (sess, eng)
        });
        let mut states = built;
        let mut replica = ps.clone();
        for step in 0..2usize {
            // Serve against the current state, then repartition under
            // the scenario's drift and refresh the routing snapshot.
            let dealt = deal(&replica, p, 48, 16, k, 64, 31 + step as u64);
            let bt = &dealt.batches;
            let sc = &scen;
            let (next, outs, _) =
                step_ranks(p, 1, CostModel::default(), states, |ctx, (mut sess, mut eng)| {
                    let (ans, _) = eng.serve(ctx, &sess, &bt[ctx.rank][0]);
                    let upd = sc.update_for(sess.local(), step);
                    sess.repartition(ctx, &upd);
                    eng.refresh(&sess, ctx.threads);
                    ((sess, eng), ans)
                });
            states = next;
            for (r, ans) in outs.iter().enumerate() {
                for (j, &pi) in dealt.loc_probes[r].iter().enumerate() {
                    assert_eq!(
                        ans.locate[j],
                        locate_oracle(&replica, replica.point(pi)),
                        "locate drifted at p={p} step={step} rank={r}"
                    );
                }
                for (j, q) in dealt.knn_probes[r].iter().enumerate() {
                    assert!(
                        same_neighbors(&ans.knn[j], &knn_exact_by_id(&replica, q, k)),
                        "knn drifted at p={p} step={step} rank={r}"
                    );
                }
            }
            // Evolve the replica by the same pure per-point rules.
            let upd = scen.update_for(&replica, step);
            upd.apply_to(&mut replica);
        }
        // After two repartitions the refreshed engine must still be
        // exact against the evolved replica — including kNN spill,
        // whose cell adjacency survives the drift.
        let dealt = deal(&replica, p, 48, 16, k, 64, 77);
        let bt = &dealt.batches;
        let (_states, outs, _) =
            step_ranks(p, 1, CostModel::default(), states, |ctx, (sess, eng)| {
                let (ans, _) = eng.serve(ctx, &sess, &bt[ctx.rank][0]);
                ((sess, eng), ans)
            });
        for (r, ans) in outs.iter().enumerate() {
            for (j, &pi) in dealt.loc_probes[r].iter().enumerate() {
                assert_eq!(ans.locate[j], locate_oracle(&replica, replica.point(pi)));
            }
            for (j, q) in dealt.knn_probes[r].iter().enumerate() {
                assert!(
                    same_neighbors(&ans.knn[j], &knn_exact_by_id(&replica, q, k)),
                    "knn wrong after repartition at p={p} rank={r} q={j}"
                );
            }
        }
    }
}

#[test]
fn noop_step_refreshes_routing_without_index_rebuild() {
    // The delta-refresh contract: `refresh` re-derives the routing
    // snapshot every call but rebuilds the local bucket index only when
    // the shard's signature changed. A repartition with no updates on a
    // balanced session migrates nothing, so the index must survive.
    let ps = dataset(0, 700, 33);
    let p = 4;
    let k = 3;
    let cfg = PartitionConfig::default();
    let (built, _) = run_ranks_threaded(p, 1, CostModel::default(), |ctx| {
        let local = ps.mod_shard(ctx.rank, ctx.n_ranks);
        let sess = DistSession::create(ctx, &local, &cfg, 4 * p, SessionConfig::default());
        let eng = DistQueryEngine::new(&sess, EngineConfig::default(), ctx.threads);
        (sess, eng)
    });
    let (states, _, _) = step_ranks(p, 1, CostModel::default(), built, |ctx, (mut sess, mut eng)| {
        sess.repartition(ctx, &UpdateBatch::new(3));
        eng.refresh(&sess, ctx.threads);
        ((sess, eng), ())
    });
    for (r, (_, eng)) in states.iter().enumerate() {
        assert_eq!(eng.routing_refreshes(), 2, "routing not refreshed at rank {r}");
        assert_eq!(eng.index_builds(), 1, "no-op step rebuilt the index at rank {r}");
    }
    // And the refreshed engine still answers exactly.
    let dealt = deal(&ps, p, 32, 8, k, 40, 3);
    let bt = &dealt.batches;
    let (_, outs, _) = step_ranks(p, 1, CostModel::default(), states, |ctx, (sess, eng)| {
        let (ans, _) = eng.serve(ctx, &sess, &bt[ctx.rank][0]);
        ((sess, eng), ans)
    });
    for (r, ans) in outs.iter().enumerate() {
        for (j, &pi) in dealt.loc_probes[r].iter().enumerate() {
            assert_eq!(ans.locate[j], locate_oracle(&ps, ps.point(pi)));
        }
        for (j, q) in dealt.knn_probes[r].iter().enumerate() {
            assert!(same_neighbors(&ans.knn[j], &knn_exact_by_id(&ps, q, k)));
        }
    }
}
