//! Property suite for the batched SFC key kernels (`sfc::kernel`).
//!
//! The contract under test: `morton_keys_batch` is bit-identical to
//! mapping the scalar `morton_key_quantized` over the points — for
//! every dimension, every input shape (uniform, clustered,
//! duplicate-heavy, points sitting exactly on cell boundaries), every
//! domain (unit cube, shifted/scaled boxes with negative corners,
//! boxes with a degenerate dimension), and every thread count.
//! `SFC_TEST_RANKS` narrows the thread sweep the same way it narrows
//! the rank sweep of the distributed suites, so CI exercises the
//! kernels at 2 and 8 pool threads in its partitioned steps.

use sfc_part::geom::bbox::BoundingBox;
use sfc_part::sfc::kernel::{
    morton_key_quantized, morton_keys_batch, quant_bits, CyclingKernel, SfcKeyKernel, SwarKernel,
};
use sfc_part::sfc::morton::{bits_per_dim, morton_key_cycling};
use sfc_part::util::bits::quantize;
use sfc_part::util::rng::{Rng, SplitMix64};

/// Thread counts to sweep (`SFC_TEST_RANKS=2` or a comma list narrows
/// it; the kernels are thread-count-invariant, so reusing the rank
/// knob is exactly the partitioning CI wants).
fn thread_sweep() -> Vec<usize> {
    match std::env::var("SFC_TEST_RANKS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("SFC_TEST_RANKS wants integers"))
            .collect(),
        Err(_) => vec![1, 2, 4, 8],
    }
}

fn full_depth(d: usize) -> u16 {
    (d as u32 * bits_per_dim(d)) as u16
}

/// The four input shapes, as flat `n × d` coordinate buffers.
fn datasets(n: usize, d: usize, seed: u64) -> Vec<(&'static str, Vec<f64>)> {
    let mut s = SplitMix64::new(seed);
    let uniform: Vec<f64> = (0..n * d).map(|_| s.next_f64()).collect();
    let centers: Vec<f64> = (0..4 * d).map(|_| s.next_f64()).collect();
    let clustered: Vec<f64> = (0..n)
        .flat_map(|i| {
            let c = (i % 4) * d;
            (0..d).map(|k| centers[c + k] + s.normal(0.0, 0.03)).collect::<Vec<f64>>()
        })
        .collect();
    let distinct: Vec<f64> = (0..8 * d).map(|_| s.next_f64()).collect();
    let dups: Vec<f64> = (0..n)
        .flat_map(|i| distinct[(i % 8) * d..(i % 8 + 1) * d].to_vec())
        .collect();
    // Every coordinate an exact dyadic cell corner: the quantized and
    // cycling walks disagree here, but batch vs scalar-quantized must
    // still match bit for bit.
    let boundary: Vec<f64> = (0..n * d).map(|_| s.below(17) as f64 / 16.0).collect();
    vec![
        ("uniform", uniform),
        ("clustered", clustered),
        ("duplicate-heavy", dups),
        ("boundary-cell", boundary),
    ]
}

/// The domains each dataset runs under: the unit cube, a shifted and
/// anisotropically scaled box with negative corners, and a box with one
/// degenerate (`hi == lo`) dimension.
fn domains(d: usize) -> Vec<(&'static str, BoundingBox)> {
    let mut degenerate =
        BoundingBox { lo: vec![-0.25; d], hi: (0..d).map(|k| 1.0 + 0.5 * k as f64).collect() };
    degenerate.hi[d - 1] = degenerate.lo[d - 1];
    vec![
        ("unit", BoundingBox::unit(d)),
        (
            "general",
            BoundingBox {
                lo: (0..d).map(|k| -2.0 - 0.3 * k as f64).collect(),
                hi: (0..d).map(|k| 1.5 + 0.7 * k as f64).collect(),
            },
        ),
        ("degenerate-dim", degenerate),
    ]
}

#[test]
fn batch_matches_scalar_bit_for_bit() {
    let threads = thread_sweep();
    for d in [2usize, 3, 5, 10] {
        let n = 3000;
        for (dname, coords) in datasets(n, d, 100 + d as u64) {
            for (bname, domain) in domains(d) {
                for depth in [full_depth(d), 9, 1] {
                    let scalar: Vec<u128> = coords
                        .chunks_exact(d)
                        .map(|q| morton_key_quantized(q, &domain, depth))
                        .collect();
                    for &th in &threads {
                        let batch = morton_keys_batch(&coords, d, &domain, depth, th);
                        assert!(
                            batch == scalar,
                            "batch != scalar: d={d} data={dname} domain={bname} \
                             depth={depth} threads={th}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn quantize_edge_cases() {
    // Degenerate interval: everything collapses to cell 0.
    assert_eq!(quantize(0.7, 1.0, 0.0, 8), 0);
    assert_eq!(quantize(0.7, 0.5, 0.5, 8), 0);
    // Out-of-domain values clamp to the end cells.
    assert_eq!(quantize(-3.0, 0.0, 1.0, 8), 0);
    assert_eq!(quantize(42.0, 0.0, 1.0, 8), 255);
    // The closed upper bound maps v == hi into the top cell.
    assert_eq!(quantize(1.0, 0.0, 1.0, 8), 255);
    assert_eq!(quantize(2.5, -2.5, 2.5, 1), 1);
    // Zero-bit grids have a single cell.
    assert_eq!(quantize(0.7, 0.0, 1.0, 0), 0);
    // quant_bits: ceil(depth/d) capped by the u64 grid and u128 key.
    assert_eq!(quant_bits(3, 9), 3);
    assert_eq!(quant_bits(3, 10), 4);
    assert_eq!(quant_bits(1, 128), 63);
    assert_eq!(quant_bits(2, 128), 63);
    assert_eq!(quant_bits(4, 128), 32);
}

#[test]
fn batched_keys_monotone_along_each_axis() {
    // With every other coordinate fixed, the Morton key is a
    // non-decreasing function of any single coordinate: quantization is
    // monotone and each dimension's bits occupy a fixed disjoint set of
    // key positions.
    let mut s = SplitMix64::new(7);
    for d in [2usize, 3, 5] {
        let depth = full_depth(d);
        let domain = BoundingBox::unit(d);
        for axis in 0..d {
            let base: Vec<f64> = (0..d).map(|_| s.next_f64()).collect();
            let steps = 257;
            let mut coords = Vec::with_capacity(steps * d);
            for i in 0..steps {
                let mut p = base.clone();
                p[axis] = i as f64 / (steps - 1) as f64;
                coords.extend_from_slice(&p);
            }
            let keys = morton_keys_batch(&coords, d, &domain, depth, 4);
            for w in keys.windows(2) {
                assert!(w[0] <= w[1], "keys decreased along axis {axis} in {d}-D");
            }
            assert!(keys[0] < keys[steps - 1], "axis {axis} in {d}-D never moved the key");
        }
    }
}

#[test]
fn cycling_kernel_batch_matches_scalar_and_is_thread_invariant() {
    let threads = thread_sweep();
    let d = 3;
    let depth = full_depth(d);
    let domain = BoundingBox { lo: vec![-1.0; d], hi: vec![3.5; d] };
    let mut s = SplitMix64::new(23);
    let coords: Vec<f64> = (0..9000 * d).map(|_| 4.5 * s.next_f64() - 1.0).collect();
    let scalar: Vec<u128> =
        coords.chunks_exact(d).map(|q| morton_key_cycling(q, &domain, depth)).collect();
    for &th in &threads {
        let batch = CyclingKernel.keys_batch(&coords, d, &domain, depth, th);
        assert!(batch == scalar, "cycling batch diverged at {th} threads");
    }
}

#[test]
fn swar_agrees_with_cycling_off_cell_boundaries() {
    // Random 53-bit-mantissa points never sit exactly on a dyadic cell
    // boundary at these depths, so the two kernels must agree exactly
    // on the unit cube — the oracle relation the quantized semantics
    // are allowed to break only *on* boundaries.
    let threads = thread_sweep();
    let mut s = SplitMix64::new(31);
    for d in [2usize, 3] {
        let depth = full_depth(d);
        let domain = BoundingBox::unit(d);
        let coords: Vec<f64> = (0..5000 * d).map(|_| s.next_f64()).collect();
        let th = *threads.last().unwrap_or(&1);
        let swar = SwarKernel.keys_batch(&coords, d, &domain, depth, th);
        let cyc = CyclingKernel.keys_batch(&coords, d, &domain, depth, th);
        assert!(swar == cyc, "kernels disagreed on random unit-cube points in {d}-D");
    }
}
