"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference here with identical
signature; pytest asserts allclose between the two across shape/dtype
sweeps (hypothesis), and the Rust integration tests check the AOT
artifacts against scalar Rust implementations of the same math.
"""

import jax.numpy as jnp


def spmv_bell_ref(blocks, cols, x):
    """Block-ELL SpMV reference.

    blocks: f32[NR, KMAX, BS, BS] — dense blocks of each block row.
    cols:   i32[NR, KMAX] — block-column index of each block (padding
            blocks point anywhere and hold zeros).
    x:      f32[N] with N = number of block cols * BS.
    Returns f32[NR*BS].
    """
    nr, kmax, bs, _ = blocks.shape
    xb = x.reshape(-1, bs)  # [NB, BS]
    gathered = xb[cols]  # [NR, KMAX, BS]
    # y[r] = sum_k blocks[r,k] @ gathered[r,k]
    y = jnp.einsum("rkij,rkj->ri", blocks, gathered)
    return y.reshape(nr * bs)


def dist2_ref(queries, candidates):
    """Pairwise squared L2 distances.

    queries: f32[Q, D]; candidates: f32[C, D] -> f32[Q, C].
    """
    qq = jnp.sum(queries * queries, axis=1, keepdims=True)  # [Q,1]
    cc = jnp.sum(candidates * candidates, axis=1)  # [C]
    qc = queries @ candidates.T  # [Q,C]
    return qq + cc[None, :] - 2.0 * qc


def morton_ref(coords, bits=10):
    """Morton keys (cycling-dimension interleave, MSB first).

    coords: f32[N, D] in [0, 1). Returns uint32[N]; bit b of quantized
    dim k lands at key bit position (D*bits - 1) - (b_from_msb*D + k),
    matching ``sfc::morton::morton_key_unit`` truncated to D*bits bits.
    """
    n, d = coords.shape
    cells = 1 << bits
    q = jnp.clip((coords * cells).astype(jnp.uint32), 0, cells - 1)  # [N,D]
    key = jnp.zeros(n, dtype=jnp.uint32)
    for b in range(bits):  # b = 0 is MSB of each coordinate
        for k in range(d):
            bit = (q[:, k] >> (bits - 1 - b)) & 1
            pos = d * bits - 1 - (b * d + k)
            key = key | (bit << pos)
    return key
