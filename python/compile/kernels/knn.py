"""Tiled pairwise-L2 distance Pallas kernel — the candidate-scoring hot
spot of the paper's k-NN application (§V-A, Fig 13).

TPU shape: ``dist2 = ‖q‖² + ‖c‖² − 2 q·cᵀ`` so the inner product runs on
the MXU as a ``TQ×D @ D×TC`` matmul per tile; norms ride along on the
VPU. The grid tiles the Q×C distance matrix so each step's operands sit
in VMEM. Top-k selection happens in the L2 jax model (lax.top_k) — it is
O(Q·C·log k) on scalar units either way, and keeping it out of the
kernel keeps the kernel MXU-pure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist2_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]  # [TQ, D]
    c = c_ref[...]  # [TC, D]
    qq = jnp.sum(q * q, axis=1, keepdims=True)  # [TQ, 1]
    cc = jnp.sum(c * c, axis=1)  # [TC]
    o_ref[...] = qq + cc[None, :] - 2.0 * (q @ c.T)


@functools.partial(jax.jit, static_argnames=("tq", "tc", "interpret"))
def dist2(queries, candidates, *, tq=8, tc=128, interpret=True):
    """Pairwise squared distances f32[Q, C] (Q % tq == 0, C % tc == 0)."""
    q, d = queries.shape
    c = candidates.shape[0]
    assert q % tq == 0 and c % tc == 0, (q, c, tq, tc)
    return pl.pallas_call(
        _dist2_kernel,
        grid=(q // tq, c // tc),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, c), jnp.float32),
        interpret=interpret,
    )(queries, candidates)
