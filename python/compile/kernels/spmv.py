"""Block-ELL SpMV Pallas kernel — the hot spot of the paper's §V-B
distributed sparse-matrix × dense-vector application.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper tunes a
cache-blocked SpMV for KNL's MCDRAM; on TPU the same insight becomes a
*block* layout that feeds the MXU dense ``BS×BS @ BS`` products out of
VMEM. Rows are grouped into strips of ``BS``; each strip holds ``KMAX``
dense blocks (padded with zero blocks), so one grid step streams one
strip of blocks HBM→VMEM (the ``BlockSpec``) and runs ``KMAX`` MXU
matmuls. Power-law row skew is handled by the *Rust coordinator* (strip
splitting + partial-sum merges), not by inflating KMAX.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(cols_ref, blocks_ref, x_ref, y_ref):
    """One grid step: block row r.

    blocks_ref: f32[1, KMAX, BS, BS] (this strip's blocks, in VMEM)
    cols_ref:   i32[1, KMAX]
    x_ref:      f32[N] (whole vector; VMEM-resident at these sizes)
    y_ref:      f32[1, BS] output strip
    """
    kmax = blocks_ref.shape[1]
    bs = blocks_ref.shape[2]

    def body(k, acc):
        c = cols_ref[0, k]
        xk = pl.load(x_ref, (pl.dslice(c * bs, bs),))
        return acc + blocks_ref[0, k] @ xk

    acc = jax.lax.fori_loop(0, kmax, body, jnp.zeros((bs,), jnp.float32))
    y_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_bell(blocks, cols, x, *, interpret=True):
    """y = A @ x with A in block-ELL form (see ref.spmv_bell_ref)."""
    nr, kmax, bs, _ = blocks.shape
    return pl.pallas_call(
        _spmv_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((1, kmax), lambda r: (r, 0)),
            pl.BlockSpec((1, kmax, bs, bs), lambda r: (r, 0, 0, 0)),
            pl.BlockSpec(x.shape, lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, bs), jnp.float32),
        interpret=interpret,
    )(cols, blocks, x).reshape(nr * bs)


def pack_bell(row_ptr, col_idx, vals, n, bs, kmax):
    """Host-side packer: CSR -> block-ELL (numpy, build path only).

    Returns (blocks[NR,KMAX,BS,BS], cols[NR,KMAX], overflow) where
    overflow lists (block_row, block_col) pairs that did not fit in KMAX
    — the coordinator reroutes those through extra strips.
    """
    import numpy as np

    nb = (n + bs - 1) // bs
    nr = nb
    blocks = np.zeros((nr, kmax, bs, bs), np.float32)
    cols = np.zeros((nr, kmax), np.int32)
    slot_of = {}  # (r, bc) -> slot
    used = np.zeros(nr, np.int32)
    overflow = []
    for r in range(len(row_ptr) - 1):
        br = r // bs
        for e in range(row_ptr[r], row_ptr[r + 1]):
            c, v = col_idx[e], vals[e]
            bc = c // bs
            key = (br, bc)
            slot = slot_of.get(key)
            if slot is None:
                if used[br] >= kmax:
                    overflow.append((br, bc, r % bs, c % bs, v))
                    continue
                slot = used[br]
                used[br] += 1
                slot_of[key] = slot
                cols[br, slot] = bc
            blocks[br, slot, r % bs, c % bs] += v
    return blocks, cols, overflow
