"""Batch Morton-key generation Pallas kernel (§III-B / §V-A).

Quantize each coordinate to ``bits`` bits and interleave MSB-first with
cycling dimensions — the same key layout as the Rust
``sfc::morton::morton_key_unit`` truncated to ``D*bits`` bits, so the
coordinator can offload bulk key generation (query presorting, §V-A) to
the PJRT executable and binary-search the results directly.

Pure VPU work (shifts/masks); the grid tiles the point batch.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _morton_kernel(c_ref, o_ref, *, bits):
    pts = c_ref[...]  # f32[TN, D]
    d = pts.shape[1]
    cells = jnp.uint32(1 << bits)
    q = jnp.clip((pts * cells.astype(jnp.float32)).astype(jnp.uint32), 0, cells - 1)
    key = jnp.zeros(pts.shape[0], jnp.uint32)
    for b in range(bits):  # unrolled: bits is static
        for k in range(d):
            bit = (q[:, k] >> (bits - 1 - b)) & 1
            pos = d * bits - 1 - (b * d + k)
            key = key | (bit << pos)
    o_ref[...] = key


@functools.partial(jax.jit, static_argnames=("bits", "tn", "interpret"))
def morton_keys(coords, *, bits=10, tn=256, interpret=True):
    """uint32 Morton keys for f32[N, D] coords in [0,1); N % tn == 0."""
    n, d = coords.shape
    assert n % tn == 0 and d * bits <= 32
    kern = functools.partial(_morton_kernel, bits=bits)
    return pl.pallas_call(
        kern,
        grid=(n // tn,),
        in_specs=[pl.BlockSpec((tn, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(coords)
