"""AOT compile path: lower the L2 jax functions to HLO **text** and write
them to artifacts/ for the Rust PJRT runtime.

HLO text, NOT ``lowered.compile()``/``.serialize()``: the image's
xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id protos; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Artifacts and their fixed shapes are listed in ``artifacts/manifest.txt``
as tab-separated ``name<TAB>inputs<TAB>outputs`` lines the Rust side
parses. Shapes here are the serving tile sizes; the coordinator tiles
larger problems over repeated executions.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---- Fixed serving shapes (tile sizes for the rust coordinator) ----
SPMV_NR = 32  # block rows per strip batch
SPMV_KMAX = 8  # blocks per block row
SPMV_BS = 32  # block edge (MXU tile)
SPMV_N = SPMV_NR * SPMV_BS  # vector length per tile

KNN_Q = 64  # queries per batch
KNN_C = 1024  # candidates per batch
KNN_D = 4  # padded coordinate dim (3-D points pad one zero)
KNN_K = 8  # neighbors returned

MORTON_N = 1024
MORTON_D = 3
MORTON_BITS = 10


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries():
    """(name, fn, example_args, input desc, output desc) per artifact."""
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return [
        (
            "spmv_bell",
            lambda blocks, cols, x: (model.spmv(blocks, cols, x),),
            (
                spec((SPMV_NR, SPMV_KMAX, SPMV_BS, SPMV_BS), f32),
                spec((SPMV_NR, SPMV_KMAX), jnp.int32),
                spec((SPMV_N,), f32),
            ),
            f"blocks:f32[{SPMV_NR},{SPMV_KMAX},{SPMV_BS},{SPMV_BS}] cols:i32[{SPMV_NR},{SPMV_KMAX}] x:f32[{SPMV_N}]",
            f"y:f32[{SPMV_N}]",
        ),
        (
            "pagerank_step",
            lambda blocks, cols, x, d: (model.pagerank_step(blocks, cols, x, d),),
            (
                spec((SPMV_NR, SPMV_KMAX, SPMV_BS, SPMV_BS), f32),
                spec((SPMV_NR, SPMV_KMAX), jnp.int32),
                spec((SPMV_N,), f32),
                spec((), f32),
            ),
            f"blocks:f32[{SPMV_NR},{SPMV_KMAX},{SPMV_BS},{SPMV_BS}] cols:i32[{SPMV_NR},{SPMV_KMAX}] x:f32[{SPMV_N}] damping:f32[]",
            f"x':f32[{SPMV_N}]",
        ),
        (
            "knn_topk",
            lambda q, c: model.knn_query(q, c, KNN_K),
            (spec((KNN_Q, KNN_D), f32), spec((KNN_C, KNN_D), f32)),
            f"queries:f32[{KNN_Q},{KNN_D}] candidates:f32[{KNN_C},{KNN_D}]",
            f"dist2:f32[{KNN_Q},{KNN_K}] idx:i32[{KNN_Q},{KNN_K}]",
        ),
        (
            "morton_keys",
            lambda c: (model.morton_batch(c, MORTON_BITS),),
            (spec((MORTON_N, MORTON_D), f32),),
            f"coords:f32[{MORTON_N},{MORTON_D}]",
            f"keys:u32[{MORTON_N}]",
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--out", default=None, help="compat: single-artifact output path (ignored)")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for name, fn, example, ins, outs in entries():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}\t{ins}\t{outs}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
