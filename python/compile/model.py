"""Layer 2 — the jax compute graphs the coordinator executes via PJRT.

Each function composes the L1 Pallas kernels into the application-level
step the Rust hot path needs:

* :func:`pagerank_step` — one damped power iteration over the block-ELL
  shard (the §V-B SpMV application).
* :func:`knn_query` — candidate scoring + top-k for a batch of queries
  (the §V-A k-NN application).
* :func:`morton_batch` — bulk SFC key generation for query presorting.

These are lowered once by ``aot.py`` to HLO text with fixed shapes;
Python never runs at request time.
"""

import jax
import jax.numpy as jnp

from compile.kernels import knn as knn_kernel
from compile.kernels import morton as morton_kernel
from compile.kernels import spmv as spmv_kernel


def pagerank_step(blocks, cols, x, damping):
    """x' = damping · (A x) + (1 − damping)/n, renormalized to sum 1.

    The renormalization folds dangling-node mass back in, matching the
    Rust sequential oracle (graph::pagerank::pagerank_seq).
    """
    n = x.shape[0]
    y = spmv_kernel.spmv_bell(blocks, cols, x)
    y = damping * y + (1.0 - damping) / n
    return y / jnp.sum(y)


def spmv(blocks, cols, x):
    """Raw block-ELL SpMV (partial products; coordinator sums strips)."""
    return spmv_kernel.spmv_bell(blocks, cols, x)


def knn_query(queries, candidates, k):
    """(dist2, idx) of the k nearest candidates per query.

    queries: f32[Q, D]; candidates: f32[C, D]; returns
    (f32[Q, k], i32[Q, k]) sorted by increasing distance.

    Top-k via sort_key_val rather than lax.top_k: the modern ``topk`` HLO
    op carries a ``largest`` attribute the xla_extension 0.5.1 text
    parser rejects, while ``sort`` round-trips fine (see aot.py header).
    """
    d2 = knn_kernel.dist2(queries, candidates)
    c = d2.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2_sorted, idx_sorted = jax.lax.sort_key_val(d2, iota, dimension=1)
    del c
    return d2_sorted[:, : int(k)], idx_sorted[:, : int(k)]


def morton_batch(coords, bits=10):
    """uint32 Morton keys for a batch of points."""
    return morton_kernel.morton_keys(coords, bits=bits)
