"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes/dtypes per the repro contract; deadline is
disabled because interpret-mode pallas tracing is slow on first call.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import knn, morton, ref, spmv

jax.config.update("jax_platform_name", "cpu")

SET = settings(deadline=None, max_examples=10)


# ---------------------------------------------------------------------
# spmv_bell
# ---------------------------------------------------------------------


def random_bell(rng, nr, kmax, bs, density=0.6):
    nb = nr  # square: block cols == block rows
    blocks = np.zeros((nr, kmax, bs, bs), np.float32)
    cols = np.zeros((nr, kmax), np.int32)
    for r in range(nr):
        used = rng.choice(nb, size=min(kmax, nb), replace=False)
        k_used = rng.integers(1, kmax + 1)
        for k in range(k_used):
            cols[r, k] = used[k % len(used)]
            if rng.random() < density:
                blocks[r, k] = rng.standard_normal((bs, bs)).astype(np.float32)
    x = rng.standard_normal(nb * bs).astype(np.float32)
    return jnp.array(blocks), jnp.array(cols), jnp.array(x)


@SET
@given(
    nr=st.sampled_from([1, 2, 4, 8]),
    kmax=st.sampled_from([1, 2, 4]),
    bs=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmv_matches_ref(nr, kmax, bs, seed):
    rng = np.random.default_rng(seed)
    blocks, cols, x = random_bell(rng, nr, kmax, bs)
    got = spmv.spmv_bell(blocks, cols, x)
    want = ref.spmv_bell_ref(blocks, cols, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_spmv_zero_blocks_zero_result():
    blocks = jnp.zeros((4, 2, 8, 8), jnp.float32)
    cols = jnp.zeros((4, 2), jnp.int32)
    x = jnp.ones(32, jnp.float32)
    assert float(jnp.abs(spmv.spmv_bell(blocks, cols, x)).max()) == 0.0


def test_pack_bell_roundtrip_dense_product():
    rng = np.random.default_rng(3)
    n, bs, kmax = 64, 8, 8
    dense = np.zeros((n, n), np.float32)
    # Sprinkle ~5 nnz per row.
    for r in range(n):
        for c in rng.choice(n, size=5, replace=False):
            dense[r, c] = rng.standard_normal()
    # CSR arrays.
    row_ptr = [0]
    col_idx, vals = [], []
    for r in range(n):
        nz = np.nonzero(dense[r])[0]
        col_idx.extend(nz.tolist())
        vals.extend(dense[r, nz].tolist())
        row_ptr.append(len(col_idx))
    blocks, cols, overflow = spmv.pack_bell(row_ptr, col_idx, vals, n, bs, kmax)
    assert not overflow  # kmax=8 block cols max with 5 nnz/row
    x = rng.standard_normal(n).astype(np.float32)
    got = spmv.spmv_bell(jnp.array(blocks), jnp.array(cols), jnp.array(x))
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-4)


def test_pack_bell_overflow_reported():
    # A row touching more than KMAX block-columns must overflow.
    n, bs, kmax = 32, 4, 2
    row_ptr = [0, 4] + [4] * (n - 1)
    col_idx = [0, 8, 16, 24]  # four distinct block cols, kmax=2
    vals = [1.0, 1.0, 1.0, 1.0]
    _, _, overflow = spmv.pack_bell(row_ptr, col_idx, vals, n, bs, kmax)
    assert len(overflow) == 2


# ---------------------------------------------------------------------
# knn dist2
# ---------------------------------------------------------------------


@SET
@given(
    q=st.sampled_from([8, 16, 32]),
    c=st.sampled_from([128, 256]),
    d=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dist2_matches_ref(q, c, d, seed):
    rng = np.random.default_rng(seed)
    qs = jnp.array(rng.random((q, d)), jnp.float32)
    cs = jnp.array(rng.random((c, d)), jnp.float32)
    got = knn.dist2(qs, cs, tq=8, tc=128)
    want = ref.dist2_ref(qs, cs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_dist2_self_distance_zero():
    pts = jnp.array(np.random.default_rng(1).random((8, 4)), jnp.float32)
    d2 = knn.dist2(pts, jnp.tile(pts, (16, 1)), tq=8, tc=128)
    diag = jnp.array([d2[i, i] for i in range(8)])
    np.testing.assert_allclose(diag, np.zeros(8), atol=1e-5)


def test_topk_model_orders():
    from compile import model

    rng = np.random.default_rng(5)
    qs = jnp.array(rng.random((8, 4)), jnp.float32)
    cs = jnp.array(rng.random((128, 4)), jnp.float32)
    d2, idx = model.knn_query(qs, cs, 4)
    full = np.asarray(ref.dist2_ref(qs, cs))
    for i in range(8):
        want = np.sort(full[i])[:4]
        np.testing.assert_allclose(np.asarray(d2[i]), want, rtol=1e-4, atol=1e-5)
        assert np.all(np.diff(np.asarray(d2[i])) >= -1e-6)
        # idx consistent with distances
        np.testing.assert_allclose(
            full[i, np.asarray(idx[i])], np.asarray(d2[i]), rtol=1e-4, atol=1e-5
        )


# ---------------------------------------------------------------------
# morton keys
# ---------------------------------------------------------------------


@SET
@given(
    d=st.sampled_from([2, 3]),
    bits=st.sampled_from([4, 8, 10]),
    seed=st.integers(0, 2**31 - 1),
)
def test_morton_matches_ref(d, bits, seed):
    rng = np.random.default_rng(seed)
    coords = jnp.array(rng.random((256, d)), jnp.float32)
    got = morton.morton_keys(coords, bits=bits, tn=256)
    want = ref.morton_ref(coords, bits=bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_morton_order_is_z_order_2d():
    # Quadrant representatives must sort BL < TL < BR < RT with the
    # cycling x-then-y convention (x in the MSB lane).
    coords = jnp.array(
        [[0.2, 0.2], [0.2, 0.8], [0.8, 0.2], [0.8, 0.8]] * 64, jnp.float32
    )
    keys = np.asarray(morton.morton_keys(coords, bits=8, tn=256))
    bl, tl, br, tr = keys[0], keys[1], keys[2], keys[3]
    assert bl < tl < br < tr


def test_morton_monotone_along_axis():
    xs = np.linspace(0, 0.999, 256, dtype=np.float32)
    coords = jnp.array(np.stack([xs, np.zeros_like(xs), np.zeros_like(xs)], 1))
    keys = np.asarray(morton.morton_keys(coords, bits=10, tn=256))
    assert np.all(np.diff(keys.astype(np.int64)) >= 0)


# ---------------------------------------------------------------------
# model-level: pagerank step
# ---------------------------------------------------------------------


def test_pagerank_step_conserves_mass():
    from compile import model

    rng = np.random.default_rng(11)
    blocks, cols, x = random_bell(rng, 8, 4, 8)
    # Make it stochastic-ish and positive.
    blocks = jnp.abs(blocks)
    x = jnp.abs(x) + 0.01
    x = x / jnp.sum(x)
    y = model.pagerank_step(blocks, cols, x, jnp.float32(0.85))
    assert abs(float(jnp.sum(y)) - 1.0) < 1e-5
    assert float(y.min()) > 0.0
